"""Triangle counting via masked SpGEMM: tri = Σ (L·L)⟨L⟩.

L is the strict lower triangle; (L·L)[i,j] counts k with j<k<i adjacent to
both, and the structural mask L keeps (i,j) edges — each triangle counted
exactly once. This is a TRUE masked multiply (§4.7): L is passed as the
output mask of the SpGEMM itself, so non-edge products are discarded before
any merge stage and the planner sizes out/stage capacities from nnz(L)
instead of nnz(L·L) — no post-hoc ewise intersection ever materializes the
unmasked product.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat
from ..core.mask import structural
from ..core.matops import mat_apply_local, mat_select_lower, mat_sum
from ..core.plan import spgemm as spgemm_planned
from ..obs import recorder as _obs


def triangle_count(a: DistSpMat, *, mesh: Mesh, prod_cap: int | None = None,
                   out_cap: int | None = None) -> int:
    """Count triangles of the symmetric graph ``a`` (values ignored)."""
    with _obs.span("tricount"):
        ones = lambda t: t.apply(lambda v: jnp.ones_like(v))
        l = mat_select_lower(mat_apply_local(a, ones, mesh=mesh), mesh=mesh)
        b, _plan = spgemm_planned(l, l, ARITHMETIC, mesh=mesh,
                                  mask=structural(l),
                                  prod_cap=prod_cap, out_cap=out_cap)
        return int(mat_sum(b))
