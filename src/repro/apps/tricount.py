"""Triangle counting via masked SpGEMM: tri = Σ (L·L) .* L.

L is the strict lower triangle; (L·L)[i,j] counts k with j<k<i adjacent to
both, masking by L keeps (i,j) edges — each triangle counted exactly once.
The elementwise mask is tile-aligned (no communication).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat, spgemm_2d
from ..core.coo import ewise_intersect
from ..core.matops import mat_ewise_local, mat_select_lower, mat_sum


def triangle_count(a: DistSpMat, *, mesh: Mesh, prod_cap: int = 1 << 16,
                   out_cap: int = 1 << 14) -> int:
    """Count triangles of the symmetric graph ``a`` (values ignored)."""
    ones = lambda t: t.apply(lambda v: jnp.ones_like(v))
    from ..core.matops import mat_apply_local
    l = mat_select_lower(mat_apply_local(a, ones, mesh=mesh), mesh=mesh)
    b, ok = spgemm_2d(l, l, ARITHMETIC, mesh=mesh, prod_cap=prod_cap,
                      out_cap=out_cap)
    assert bool(jnp.all(ok)), "tricount overflow"
    masked = mat_ewise_local(
        b, l, lambda t1, t2: ewise_intersect(t1, t2, jnp.multiply,
                                             out_cap=t1.cap), mesh=mesh)
    return int(mat_sum(masked))
