"""Triangle counting via masked SpGEMM: tri = Σ (L·L) .* L.

L is the strict lower triangle; (L·L)[i,j] counts k with j<k<i adjacent to
both, masking by L keeps (i,j) edges — each triangle counted exactly once.
The elementwise mask is tile-aligned (no communication).

The L·L capacities come from the planner (symbolic pass over tile nnz with
retry-on-overflow) — no hard-coded caps; pass ``prod_cap``/``out_cap`` only
to override.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat
from ..core.coo import ewise_intersect
from ..core.matops import (mat_apply_local, mat_ewise_local, mat_select_lower,
                           mat_sum)
from ..core.plan import spgemm as spgemm_planned


def triangle_count(a: DistSpMat, *, mesh: Mesh, prod_cap: int | None = None,
                   out_cap: int | None = None) -> int:
    """Count triangles of the symmetric graph ``a`` (values ignored)."""
    ones = lambda t: t.apply(lambda v: jnp.ones_like(v))
    l = mat_select_lower(mat_apply_local(a, ones, mesh=mesh), mesh=mesh)
    b, _plan = spgemm_planned(l, l, ARITHMETIC, mesh=mesh,
                              prod_cap=prod_cap, out_cap=out_cap)
    masked = mat_ewise_local(
        b, l, lambda t1, t2: ewise_intersect(t1, t2, jnp.multiply,
                                             out_cap=t1.cap), mesh=mesh)
    return int(mat_sum(masked))
