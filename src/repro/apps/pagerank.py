"""PageRank via distributed SpMV (paper §7.5, Fig 10).

r ← α · A_colnorm r + (1-α)/n · 1  (+ dangling mass redistribution),
one spmv_iter per step (SpMV + layout transpose), vectors fully distributed.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat, DistVec, spmv_iter
from ..core.dist import make_grid
from ..core.matops import mat_reduce, mat_scale_cols, vec_apply, vec_sum
from ..core.plan import spmv_variant
from ..robust.recover import CheckpointedLoop


def pagerank(a: DistSpMat, *, mesh: Mesh, alpha: float = 0.85,
             tol: float = 1e-8, max_iters: int = 100,
             checkpoint_dir: str | None = None,
             checkpoint_every: int = 1,
             elastic: bool = False, watchdog=None) -> np.ndarray:
    """PageRank of the directed graph with edge u→v ⇔ entry (v, u) ≠ 0.

    (Build A from an edge list as A[dst, src] = 1, or pass mat_transpose of
    the usual adjacency.)

    ``checkpoint_dir`` enables per-iteration checkpoint/resume
    (robust/recover.CheckpointedLoop): re-running after a crash with the
    same directory resumes from the last saved iteration and converges to
    the bitwise-identical result of an uninterrupted run. The checkpointed
    state is the *global* rank vector — mesh-independent, so a crashed run
    can resume on a different (smaller) process grid.

    ``elastic=True`` additionally survives an in-process TopologyError
    (injected device loss, exhausted exchange deadlines): the loop
    checkpoints, regrids the normalized matrix onto the next smaller square
    grid, and re-runs the interrupted iteration there.
    """
    n = a.shape[0]
    teleport = (1.0 - alpha) / n

    # grid-dependent operands live in a rebuildable context so the elastic
    # path can swap in a smaller grid mid-run
    ctx: dict = {}

    def setup(an: DistSpMat, dangling_g: np.ndarray, mesh2: Mesh):
        grid2 = an.grid
        ctx.update(
            mesh=mesh2, grid=grid2, an=an,
            dangling=DistVec.from_global(dangling_g, grid2, layout="col",
                                         mesh=mesh2),
            # planner rule: the local SpMV flavor whose sort the tiles
            # already have is free
            variant=spmv_variant(an))

    # out-degree of source vertices = column sums of A(dst, src)
    deg = mat_reduce(a, axis=0, add=ARITHMETIC.add, mesh=mesh)  # layout col
    inv = vec_apply(deg, lambda d: jnp.where(d > 0, 1.0 / jnp.maximum(d, 1e-30),
                                             0.0))
    an0 = mat_scale_cols(a, inv, mesh=mesh)       # column-stochastic
    # dangling indicator on the REAL vertices only (padding tail excluded)
    dangling_g0 = (deg.to_global()[:n] == 0).astype(np.float32)
    setup(an0, dangling_g0, mesh)

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact.
    # state["r"] is the GLOBAL (n,) rank vector: re-sharding it onto
    # whatever grid ctx currently holds is what makes resume mesh-free.
    def body(it, state):
        r_g = np.asarray(state["r"], np.float32)
        grid2, mesh2 = ctx["grid"], ctx["mesh"]
        r = DistVec.from_global(r_g, grid2, layout="col", mesh=mesh2)
        dangling = float(vec_sum(
            DistVec(r.data * ctx["dangling"].data, n, grid2, "col")))
        r_new = spmv_iter(ctx["an"], r, ARITHMETIC, mesh=mesh2,  # to 'col'
                          variant=ctx["variant"])
        add_const = teleport + alpha * dangling / n
        r_new = vec_apply(r_new, lambda x: alpha * x + add_const)
        r_new_g = r_new.to_global()[:n]           # drops the padding tail
        delta = float(np.abs(r_new_g - r_g).sum())
        return {"r": r_new_g}, delta < tol

    on_topology = None
    if elastic:
        def on_topology(state, err):
            q = max(ctx["grid"][0] // 2, 1)
            new_mesh = make_grid(q, q)
            # regrid the already-normalized matrix: entry values move
            # bit-identically, no re-normalization drift
            an2 = ctx["an"].regrid((q, q), mesh=new_mesh)
            setup(an2, ctx["dangling"].to_global()[:n], new_mesh)
            return state

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every,
                            watchdog=watchdog, on_topology=on_topology,
                            name="pagerank")
    state = loop.run({"r": np.full(n, 1.0 / n, np.float32)}, body, max_iters)
    out = np.asarray(state["r"], np.float32)
    return out / out.sum()
