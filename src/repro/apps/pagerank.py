"""PageRank via distributed SpMV (paper §7.5, Fig 10).

r ← α · A_colnorm r + (1-α)/n · 1  (+ dangling mass redistribution),
one spmv_iter per step (SpMV + layout transpose), vectors fully distributed.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat, DistVec, spmv_iter
from ..core.dist import shard_put
from ..core.matops import mat_reduce, mat_scale_cols, vec_apply, vec_sum
from ..core.plan import spmv_variant
from ..core.spmv import transpose_layout
from ..robust.recover import CheckpointedLoop


def pagerank(a: DistSpMat, *, mesh: Mesh, alpha: float = 0.85,
             tol: float = 1e-8, max_iters: int = 100,
             checkpoint_dir: str | None = None,
             checkpoint_every: int = 1) -> np.ndarray:
    """PageRank of the directed graph with edge u→v ⇔ entry (v, u) ≠ 0.

    (Build A from an edge list as A[dst, src] = 1, or pass mat_transpose of
    the usual adjacency.)

    ``checkpoint_dir`` enables per-iteration checkpoint/resume
    (robust/recover.CheckpointedLoop): re-running after a crash with the
    same directory resumes from the last saved iteration and converges to
    the bitwise-identical result of an uninterrupted run.
    """
    n = a.shape[0]
    grid = a.grid
    # out-degree of source vertices = column sums of A(dst, src)
    deg = mat_reduce(a, axis=0, add=ARITHMETIC.add, mesh=mesh)  # layout col
    inv = vec_apply(deg, lambda d: jnp.where(d > 0, 1.0 / jnp.maximum(d, 1e-30),
                                             0.0))
    an = mat_scale_cols(a, inv, mesh=mesh)        # column-stochastic
    valid = DistVec.from_global(np.ones(n, np.float32), grid, layout="col",
                                mesh=mesh)        # 0 on padding tail
    dangling_mask = DistVec(
        (deg.data == 0).astype(jnp.float32) * valid.data, n, grid, "col")

    r = DistVec.from_global(np.full(n, 1.0 / n, np.float32), grid,
                            layout="col", mesh=mesh)
    teleport = (1.0 - alpha) / n
    # planner rule: pick the local SpMV flavor whose sort the tiles get free
    variant = spmv_variant(an)

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact
    def body(it, state):
        r = shard_put(DistVec(jnp.asarray(state["r"]), n, grid, "col"), mesh)
        dangling = float(vec_sum(
            DistVec(r.data * dangling_mask.data, n, grid, "col")))
        r_new = spmv_iter(an, r, ARITHMETIC, mesh=mesh,   # back to 'col'
                          variant=variant)
        add_const = teleport + alpha * dangling / n
        r_new = vec_apply(r_new, lambda x: alpha * x + add_const)
        # zero the padding tail introduced by from_global rounding
        delta = float(jnp.sum(jnp.abs(r_new.data - r.data)))
        return {"r": r_new.data}, delta < tol

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every)
    state = loop.run({"r": r.data}, body, max_iters)
    r = DistVec(jnp.asarray(state["r"]), n, grid, "col")
    out = r.to_global()[:n]
    return out / out.sum()
