"""HipMCL — Markov clustering at scale (paper §7.5, Fig 9; Azad et al [38]).

MCL iterates on a column-stochastic matrix:
  expansion:  C ← C·C            (distributed SpGEMM — the dominant cost)
  inflation:  C ← C.^r, column-renormalized
  pruning:    drop entries below threshold (keeps the iterate sparse)
until the iterate is (near-)idempotent; clusters are the weakly-connected
components of the converged attractor pattern (extracted with FastSV).

The expansion can run batched (``nbatch>1``) — the paper's answer for
outputs exceeding aggregate memory (Friendster: 4 batches, §7.2). GPU
offload in the paper ⇒ the kernels/semiring_matmul Pallas path here.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat
from ..core.coo import SENTINEL
from ..core.dist import shard_put
from ..core.mask import value_mask
from ..core.matops import (mat_apply_local, mat_ewise_local, mat_reduce,
                           mat_scale_cols, mat_sum, mat_transpose, vec_apply)
from ..core.plan import spgemm as spgemm_planned
from ..robust.recover import CheckpointedLoop
from .fastsv import fastsv


def _normalize_cols(a: DistSpMat, *, mesh: Mesh) -> DistSpMat:
    s = mat_reduce(a, axis=0, add=ARITHMETIC.add, mesh=mesh)
    inv = vec_apply(s, lambda d: jnp.where(d > 0, 1.0 / jnp.maximum(d, 1e-30),
                                           0.0))
    return mat_scale_cols(a, inv, mesh=mesh)


def hipmcl(a: DistSpMat, *, mesh: Mesh, inflation: float = 2.0,
           prune_threshold: float = 1e-4, max_iters: int = 20,
           prod_cap: int | None = None, out_cap: int | None = None,
           tol: float = 1e-5,
           checkpoint_dir: str | None = None,
           checkpoint_every: int = 1) -> np.ndarray:
    """Cluster the graph; returns per-vertex cluster labels.

    Expansion capacities are re-planned each iteration from the current
    iterate's tile nnz (pruning keeps them shrinking) and grown on overflow
    — the caps in the signature are optional overrides only.

    ``checkpoint_dir`` checkpoints the iterate each MCL iteration (the
    paper's flagship runs for days — robust/recover.CheckpointedLoop).
    State restores manifest-driven (no shape template) because the
    re-planned capacities change the iterate's array shapes between
    iterations; a crashed run resumed with the same directory finishes
    bitwise-identically.
    """
    n = a.shape[0]
    # callers should include self-loops in `a` (MCL standard practice)
    c = _normalize_cols(a, mesh=mesh)
    # value-predicate mask (§4.7): entries of the expansion C·C already
    # below the prune threshold are dropped inside the multiply's final
    # merge compaction — the bulk of MCL's prune happens fused, keeping the
    # returned iterate (and the next expansion's caps) small. C·C is
    # column-stochastic, so the threshold means the same thing it does in
    # the explicit prune below (which still runs post-inflation, where
    # renormalization can push further entries under the bar).
    expansion_mask = value_mask(lambda v: v > prune_threshold)

    def pack_state(c: DistSpMat, prev_sum: float) -> dict:
        # flat arrays only: per-iteration re-planning changes cap shapes,
        # so restore is manifest-driven (checkpoint.restore_flat) — the
        # order tag rides along as bytes
        return {"row": c.row, "col": c.col, "val": c.val, "nnz": c.nnz,
                "order": np.frombuffer(c.order.encode(), dtype=np.uint8),
                "prev_sum": np.float64(prev_sum)}

    def unpack_state(state: dict):
        order = bytes(np.asarray(state["order"])).decode()
        c = shard_put(DistSpMat(
            jnp.asarray(state["row"]), jnp.asarray(state["col"]),
            jnp.asarray(state["val"]), jnp.asarray(state["nnz"]),
            (n, n), a.grid, order=order), mesh)
        return c, float(state["prev_sum"])

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact
    def body(it, state):
        c, prev_sum = unpack_state(state)
        c2, _plan = spgemm_planned(c, c, ARITHMETIC, mesh=mesh,
                                   mask=expansion_mask,
                                   prod_cap=prod_cap, out_cap=out_cap)
        # inflation
        c2 = mat_apply_local(c2, lambda t: t.apply(lambda v: v ** inflation),
                             mesh=mesh)
        c2 = _normalize_cols(c2, mesh=mesh)
        # pruning
        c2 = mat_apply_local(
            c2, lambda t: t.prune(lambda v: v > prune_threshold), mesh=mesh)
        c2 = _normalize_cols(c2, mesh=mesh)
        chaos = float(mat_sum(mat_ewise_local(
            c2, c2, lambda t1, t2: t1.apply(lambda v: v * v), mesh=mesh)))
        done = (not np.isnan(prev_sum)) and abs(chaos - prev_sum) < tol
        return pack_state(c2, chaos), done

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every)
    state = loop.run(pack_state(c, np.nan), body, max_iters)
    c, _ = unpack_state(state)
    # clusters = connected components of the attractor pattern (symmetrized)
    ct = mat_transpose(c, mesh=mesh)
    from ..core.coo import COO
    from ..core import ewise_union
    sym = mat_ewise_local(
        c, ct, lambda t1, t2: ewise_union(t1, t2, ARITHMETIC.add,
                                          cap=t1.cap), mesh=mesh)
    return fastsv(sym, mesh=mesh)
