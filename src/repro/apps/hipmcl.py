"""HipMCL — Markov clustering at scale (paper §7.5, Fig 9; Azad et al [38]).

MCL iterates on a column-stochastic matrix:
  expansion:  C ← C·C            (distributed SpGEMM — the dominant cost)
  inflation:  C ← C.^r, column-renormalized
  pruning:    drop entries below threshold (keeps the iterate sparse)
until the iterate is (near-)idempotent; clusters are the weakly-connected
components of the converged attractor pattern (extracted with FastSV).

The expansion can run batched (``nbatch>1``) — the paper's answer for
outputs exceeding aggregate memory (Friendster: 4 batches, §7.2). GPU
offload in the paper ⇒ the kernels/semiring_matmul Pallas path here.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import ARITHMETIC, DistSpMat
from ..core.dist import make_grid
from ..core.mask import value_mask
from ..core.matops import (mat_apply_local, mat_ewise_local, mat_reduce,
                           mat_scale_cols, mat_sum, mat_transpose, vec_apply)
from ..core.plan import spgemm as spgemm_planned
from ..robust.recover import CheckpointedLoop
from .fastsv import fastsv


def _normalize_cols(a: DistSpMat, *, mesh: Mesh) -> DistSpMat:
    s = mat_reduce(a, axis=0, add=ARITHMETIC.add, mesh=mesh)
    inv = vec_apply(s, lambda d: jnp.where(d > 0, 1.0 / jnp.maximum(d, 1e-30),
                                           0.0))
    return mat_scale_cols(a, inv, mesh=mesh)


def hipmcl(a: DistSpMat, *, mesh: Mesh, inflation: float = 2.0,
           prune_threshold: float = 1e-4, max_iters: int = 20,
           prod_cap: int | None = None, out_cap: int | None = None,
           tol: float = 1e-5,
           checkpoint_dir: str | None = None,
           checkpoint_every: int = 1,
           elastic: bool = False, watchdog=None) -> np.ndarray:
    """Cluster the graph; returns per-vertex cluster labels.

    Expansion capacities are re-planned each iteration from the current
    iterate's tile nnz (pruning keeps them shrinking) and grown on overflow
    — the caps in the signature are optional overrides only.

    ``checkpoint_dir`` checkpoints the iterate each MCL iteration (the
    paper's flagship runs for days — robust/recover.CheckpointedLoop).
    The checkpointed state is the GLOBAL int64 COO of the iterate —
    mesh-independent, and necessarily manifest-driven (restore_flat, no
    shape template) because pruning changes nnz between iterations; a
    crashed run resumed with the same directory on the same grid finishes
    bitwise-identically. ``elastic=True`` additionally survives an
    in-process TopologyError by re-assembling the iterate on the next
    smaller square grid (same-result, though not bitwise — SpGEMM merge
    order is grid-dependent in f32).
    """
    n = a.shape[0]

    # grid-dependent context, rebuildable so the elastic path can shrink it
    ctx = {"mesh": mesh, "grid": a.grid}

    # callers should include self-loops in `a` (MCL standard practice)
    c = _normalize_cols(a, mesh=mesh)
    # value-predicate mask (§4.7): entries of the expansion C·C already
    # below the prune threshold are dropped inside the multiply's final
    # merge compaction — the bulk of MCL's prune happens fused, keeping the
    # returned iterate (and the next expansion's caps) small. C·C is
    # column-stochastic, so the threshold means the same thing it does in
    # the explicit prune below (which still runs post-inflation, where
    # renormalization can push further entries under the bar).
    expansion_mask = value_mask(lambda v: v > prune_threshold)

    def pack_state(c: DistSpMat, prev_sum: float) -> dict:
        # GLOBAL COO only: nnz changes between iterations (pruning), so
        # restore is manifest-driven (checkpoint.restore_flat), and global
        # coordinates make the state mesh-independent — the order tag rides
        # along as bytes
        rows, cols, vals = c.to_global_coo()
        return {"rows": rows, "cols": cols, "vals": vals,
                "order": np.frombuffer(c.order.encode(), dtype=np.uint8),
                "prev_sum": np.float64(prev_sum)}

    def unpack_state(state: dict):
        tag = bytes(np.asarray(state["order"])).decode()
        c = DistSpMat.from_global_coo(
            (n, n), state["rows"], state["cols"], state["vals"],
            ctx["grid"], mesh=ctx["mesh"],
            order=tag if tag in ("row", "col") else "row")
        return c, float(state["prev_sum"])

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact
    def body(it, state):
        mesh2 = ctx["mesh"]
        c, prev_sum = unpack_state(state)
        c2, _plan = spgemm_planned(c, c, ARITHMETIC, mesh=mesh2,
                                   mask=expansion_mask,
                                   prod_cap=prod_cap, out_cap=out_cap)
        # inflation
        c2 = mat_apply_local(c2, lambda t: t.apply(lambda v: v ** inflation),
                             mesh=mesh2)
        c2 = _normalize_cols(c2, mesh=mesh2)
        # pruning
        c2 = mat_apply_local(
            c2, lambda t: t.prune(lambda v: v > prune_threshold), mesh=mesh2)
        c2 = _normalize_cols(c2, mesh=mesh2)
        chaos = float(mat_sum(mat_ewise_local(
            c2, c2, lambda t1, t2: t1.apply(lambda v: v * v), mesh=mesh2)))
        done = (not np.isnan(prev_sum)) and abs(chaos - prev_sum) < tol
        return pack_state(c2, chaos), done

    on_topology = None
    if elastic:
        def on_topology(state, err):
            q = max(ctx["grid"][0] // 2, 1)
            ctx.update(mesh=make_grid(q, q), grid=(q, q))
            return state  # global COO — unpack lands it on the new grid

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every,
                            watchdog=watchdog, on_topology=on_topology,
                            name="hipmcl")
    state = loop.run(pack_state(c, np.nan), body, max_iters)
    c, _ = unpack_state(state)
    mesh2 = ctx["mesh"]
    # clusters = connected components of the attractor pattern (symmetrized)
    ct = mat_transpose(c, mesh=mesh2)
    from ..core import ewise_union
    sym = mat_ewise_local(
        c, ct, lambda t1, t2: ewise_union(t1, t2, ARITHMETIC.add,
                                          cap=t1.cap), mesh=mesh2)
    return fastsv(sym, mesh=mesh2)
