"""Maximal cardinality matching on bipartite graphs (paper §3.3, [22]).

Simplified Azad-Buluç iteration over CombBLAS primitives:
  repeat until no augmenting edges:
    1. every unmatched row proposes to one adjacent unmatched column
       (SpMV with (max, select-col-id): h[c] = max row id proposing to c)
    2. each column accepts one proposer; accepted pairs update mateRow/
       mateCol (piece-aligned vector updates + one distributed assign)

The paper replicates the mate vectors along process rows/columns to avoid
fine-grained traffic; here the same effect comes from the all_gather inside
the SpMV (the column block of the mate vector is materialized per process
column — an explicit, bulk-synchronous replication).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import DistSpMat, DistVec
from ..core.assign import assign
from ..core.plan import spmv_variant
from ..core.semiring import MAX_INT, Semiring
from ..core.spmv import spmv_iter, transpose_layout

_NONE = -1
MAXSEL = Semiring(MAX_INT, lambda a, b: b, "max_select2nd_i32")


def maximal_matching(a: DistSpMat, *, mesh: Mesh, max_iters: int = 64):
    """Greedy maximal matching. a: (nr × nc) bipartite adjacency.

    Returns (mate_row[nr], mate_col[nc]) with -1 = unmatched. The matching
    is maximal on the support of a (no edge joins two unmatched vertices).
    """
    nr, nc = a.shape
    grid = a.grid
    pr, pc = grid
    npad_r = a.mb * pr
    npad_c = a.nb * pc
    mate_row = DistVec.from_global(np.full(npad_r, _NONE, np.int32), grid,
                                   layout="col", mesh=mesh)
    mate_col = DistVec.from_global(np.full(npad_c, _NONE, np.int32), grid,
                                   layout="col", mesh=mesh)
    vb_r = mate_row.vb
    # global row id of each vector slot (for proposals)
    ids_r = DistVec.from_global(np.arange(npad_r, dtype=np.int32), grid,
                                layout="col", mesh=mesh)
    rcap = max(npad_r, npad_c, 64)

    from ..core.assign import extract
    from ..core.matops import mat_transpose
    from ..core.coo import SENTINEL
    at = mat_transpose(a, mesh=mesh)
    variant = spmv_variant(at)   # planner: match the transposed tile order
    ids_c = DistVec.from_global(np.arange(npad_c, dtype=np.int32), grid,
                                layout="col", mesh=mesh)
    for it in range(max_iters):
        # 1. unmatched rows broadcast their id; matched rows send -1
        prop = DistVec(jnp.where(mate_row.data == _NONE, ids_r.data, _NONE),
                       nr, grid, "col")
        # h[c] = max proposing row over N(c):  y = A^T prop via (max, 2nd)
        h = spmv_iter(at, prop, MAXSEL, mesh=mesh,       # layout 'col', len nc
                      variant=variant)
        # 2. columns accept: unmatched columns with a valid proposer
        accept = (mate_col.data == _NONE) & (h.data > _NONE) & \
            (h.data < jnp.int32(2**31 - 1))
        changed = int(jnp.sum(accept))
        if changed == 0:
            break
        # 3. accepted rows pick ONE column (max col id wins the assign merge)
        upd_idx = jnp.where(accept, h.data, SENTINEL)
        upd_val = jnp.where(accept, ids_c.data, _NONE)
        mate_row, ok = assign(mate_row, upd_idx, upd_val, mesh=mesh,
                              add=MAX_INT, route_cap=rcap)
        assert bool(jnp.all(ok))
        # 4. verification: column c keeps row r only if mate_row[r] == c
        #    (two columns may have accepted the same proposer)
        got, ok2 = extract(mate_row, upd_idx, mesh=mesh, route_cap=rcap)
        assert bool(jnp.all(ok2))
        confirmed = accept & (got == ids_c.data)
        mate_col = DistVec(jnp.where(confirmed, h.data, mate_col.data),
                           nc, grid, "col")
    return (mate_row.to_global()[:nr].astype(np.int64),
            mate_col.to_global()[:nc].astype(np.int64))
