"""FastSV connected components (paper §7.4, Fig 8; Zhang-Azad-Hu [37]).

The Shiloach-Vishkin family expressed in CombBLAS primitives — per
iteration, with parent vector f (int32 global vertex ids):

  gf = f[f]                                  (vector extract — assign.py)
  h[u] = min_{v ∈ N(u)} gf[v]                (SpMV, (min, select2nd))
  stochastic hooking:  f[f_old[u]] ⊕min= h[u]   (vector assign, accumulate)
  aggressive hooking:  f[u] ⊕min= h[u]          (piece-aligned ewise)
  shortcutting:        f[u] ⊕min= gf[u]
  converge when f stops changing.

This exercises exactly the operations the paper calls the hard-to-scale
tail (SpMV + assign/extract with skewed traffic) — the skew-aware assign
path is available via ``skew_aware=True``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import DistSpMat, DistVec
from ..core.assign import assign, extract
from ..core.dist import make_grid
from ..core.plan import spmv_variant
from ..core.semiring import MIN_INT, Semiring
from ..core.spmv import spmv_iter
from ..robust.recover import CheckpointedLoop

MIN_SELECT2ND_I32 = Semiring(MIN_INT, lambda a, b: b, "min_select2nd_i32")


def fastsv(a: DistSpMat, *, mesh: Mesh, max_iters: int = 64,
           skew_aware: bool = False,
           checkpoint_dir: str | None = None,
           checkpoint_every: int = 1,
           elastic: bool = False, watchdog=None) -> np.ndarray:
    """Connected-component labels of the *symmetric* graph ``a``.

    ``checkpoint_dir`` checkpoints the parent vector each hooking iteration
    (robust/recover.CheckpointedLoop) — a crashed run resumed with the same
    directory finishes bitwise-identically. The checkpointed state is the
    GLOBAL (n,) parent vector, mesh-independent: a run crashed on one grid
    resumes on any other (every hooking op is an exact int32 min, so even
    the cross-grid replay is bitwise). The final (cheap, idempotent)
    pointer-jumping sweep is not checkpointed.

    ``elastic=True`` survives an in-process TopologyError by regridding the
    graph onto the next smaller square grid and re-running the interrupted
    hooking iteration there.
    """
    n = a.shape[0]

    ctx: dict = {}

    def setup(a2: DistSpMat, mesh2: Mesh):
        pr, pc = a2.grid
        vb = -(-n // (pr * pc))
        ctx.update(
            mesh=mesh2, grid=a2.grid, a=a2,
            # padding tail holds self ids ≥ n: never wins a min, never
            # hooks a real vertex
            npad=vb * pr * pc,
            # worst-case hooking traffic concentrates on root pieces —
            # size the router for it (the skew-aware path offloads heavy
            # roots to broadcast)
            rcap=max(vb * pr * pc, 64),
            variant=spmv_variant(a2))  # planner: match the tile sort order

    setup(a, mesh)

    def distribute(f_g: np.ndarray) -> DistVec:
        """Global (n,) parents -> padded DistVec on the current grid."""
        tail = np.arange(n, ctx["npad"], dtype=np.int32)
        return DistVec.from_global(
            np.concatenate([np.asarray(f_g, np.int32), tail]),
            ctx["grid"], layout="col", mesh=ctx["mesh"])

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact
    def body(it, state):
        mesh2, grid2 = ctx["mesh"], ctx["grid"]
        rcap = ctx["rcap"]
        f_old = distribute(state["f"])
        # gf = f[f]  (grandparents)
        gf_vals, ok = extract(f_old, f_old.data.astype(jnp.int32),
                              mesh=mesh2, route_cap=rcap)
        assert bool(jnp.all(ok))
        gf = DistVec(gf_vals, n, grid2, "col")
        # h[u] = min over neighbors of gf — (min, select2nd) SpMV
        h = spmv_iter(ctx["a"], gf, MIN_SELECT2ND_I32, mesh=mesh2,  # 'col'
                      variant=ctx["variant"])
        # stochastic hooking: f[f_old[u]] = min(·, h[u]) — distributed assign
        f2, ok = assign(f_old, f_old.data.astype(jnp.int32), h.data,
                        mesh=mesh2, add=MIN_INT, accumulate=True,
                        skew_aware=skew_aware, route_cap=rcap)
        assert bool(jnp.all(ok))
        # aggressive hooking + shortcutting (piece-aligned, no comm)
        fd = jnp.minimum(jnp.minimum(f2.data, h.data), gf.data)
        f_new = DistVec(fd, ctx["npad"], grid2, "col")
        f_g = f_new.to_global()[:n].astype(np.int32)
        # padding entries are fixed points (own id vs INT_MAX h), so
        # convergence on the real prefix IS convergence
        return {"f": f_g}, bool(np.array_equal(f_g,
                                               np.asarray(state["f"])))

    on_topology = None
    if elastic:
        def on_topology(state, err):
            q = max(ctx["grid"][0] // 2, 1)
            new_mesh = make_grid(q, q)
            setup(ctx["a"].regrid((q, q), mesh=new_mesh), new_mesh)
            return state

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every,
                            watchdog=watchdog, on_topology=on_topology,
                            name="fastsv")
    state = loop.run({"f": np.arange(n, dtype=np.int32)}, body, max_iters)
    # final pointer jumping to full convergence
    f = distribute(state["f"])
    for _ in range(max_iters):
        gf_vals, _ = extract(f, f.data.astype(jnp.int32), mesh=ctx["mesh"],
                             route_cap=ctx["rcap"])
        gf = DistVec(gf_vals, ctx["npad"], ctx["grid"], "col")
        if bool(jnp.all(gf.data == f.data)):
            break
        f = gf
    return f.to_global()[:n].astype(np.int64)
