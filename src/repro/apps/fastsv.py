"""FastSV connected components (paper §7.4, Fig 8; Zhang-Azad-Hu [37]).

The Shiloach-Vishkin family expressed in CombBLAS primitives — per
iteration, with parent vector f (int32 global vertex ids):

  gf = f[f]                                  (vector extract — assign.py)
  h[u] = min_{v ∈ N(u)} gf[v]                (SpMV, (min, select2nd))
  stochastic hooking:  f[f_old[u]] ⊕min= h[u]   (vector assign, accumulate)
  aggressive hooking:  f[u] ⊕min= h[u]          (piece-aligned ewise)
  shortcutting:        f[u] ⊕min= gf[u]
  converge when f stops changing.

This exercises exactly the operations the paper calls the hard-to-scale
tail (SpMV + assign/extract with skewed traffic) — the skew-aware assign
path is available via ``skew_aware=True``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import DistSpMat, DistVec
from ..core.assign import assign, extract
from ..core.coo import SENTINEL
from ..core.dist import shard_put
from ..core.plan import spmv_variant
from ..core.semiring import MIN_INT, Semiring
from ..core.spmv import spmv_iter
from ..robust.recover import CheckpointedLoop

MIN_SELECT2ND_I32 = Semiring(MIN_INT, lambda a, b: b, "min_select2nd_i32")


def fastsv(a: DistSpMat, *, mesh: Mesh, max_iters: int = 64,
           skew_aware: bool = False,
           checkpoint_dir: str | None = None,
           checkpoint_every: int = 1) -> np.ndarray:
    """Connected-component labels of the *symmetric* graph ``a``.

    ``checkpoint_dir`` checkpoints the parent vector each hooking iteration
    (robust/recover.CheckpointedLoop) — a crashed run resumed with the same
    directory finishes bitwise-identically. The final (cheap, idempotent)
    pointer-jumping sweep is not checkpointed.
    """
    n = a.shape[0]
    grid = a.grid
    pr, pc = grid
    # f starts as identity; padding tail points at INT_MAX-ish self ids so
    # it never wins a min and never hooks a real vertex
    vb = -(-n // (pr * pc))
    npad = vb * pr * pc
    f0 = np.arange(npad, dtype=np.int32)
    f = DistVec.from_global(f0, grid, layout="col", mesh=mesh)
    f.data.block_until_ready()

    # worst-case hooking traffic concentrates on root pieces — size the
    # router for it (the skew-aware path offloads heavy roots to broadcast)
    rcap = max(npad, 64)
    variant = spmv_variant(a)   # planner: match the tile's sort order

    # loop body as a pure function of the flat state dict — the SAME body
    # runs bare and checkpointed, which is what makes resume bitwise-exact
    def body(it, state):
        f_old = shard_put(DistVec(jnp.asarray(state["f"]), n, grid, "col"),
                          mesh)
        # gf = f[f]  (grandparents)
        gf_vals, ok = extract(f_old, f_old.data.astype(jnp.int32), mesh=mesh,
                              route_cap=rcap)
        assert bool(jnp.all(ok))
        gf = DistVec(gf_vals, n, grid, "col")
        # h[u] = min over neighbors of gf — (min, select2nd) SpMV
        h = spmv_iter(a, gf, MIN_SELECT2ND_I32, mesh=mesh,   # layout 'col'
                      variant=variant)
        # stochastic hooking: f[f_old[u]] = min(·, h[u]) — distributed assign
        f2, ok = assign(f_old, f_old.data.astype(jnp.int32), h.data,
                        mesh=mesh, add=MIN_INT, accumulate=True,
                        skew_aware=skew_aware, route_cap=rcap)
        assert bool(jnp.all(ok))
        # aggressive hooking + shortcutting (piece-aligned, no comm)
        fd = jnp.minimum(jnp.minimum(f2.data, h.data), gf.data)
        return {"f": fd}, bool(jnp.all(fd == f_old.data))

    loop = CheckpointedLoop(checkpoint_dir, every=checkpoint_every)
    state = loop.run({"f": f.data}, body, max_iters)
    f = DistVec(jnp.asarray(state["f"]), n, grid, "col")
    # final pointer jumping to full convergence
    for _ in range(max_iters):
        gf_vals, _ = extract(f, f.data.astype(jnp.int32), mesh=mesh,
                             route_cap=rcap)
        gf = DistVec(gf_vals, n, grid, "col")
        if bool(jnp.all(gf.data == f.data)):
            break
        f = gf
    return f.to_global()[:n].astype(np.int64)
