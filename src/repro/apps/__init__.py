"""repro.apps — the paper's applications, built on repro.core (§7)."""
from .bfs import bfs_levels
from .pagerank import pagerank
from .fastsv import fastsv
from .hipmcl import hipmcl
from .tricount import triangle_count
from .matching import maximal_matching
