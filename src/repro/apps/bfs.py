"""Breadth-first search via SpMSpV (the original CombBLAS demo app).

Level-synchronous BFS: the frontier is a FullyDistSpVec, each step is one
SpMSpV over the boolean semiring followed by a piece-aligned mask against
the visited vector (no communication — the superimposed layout payoff).

Capacities are chosen by the planner (core/plan.py) from the *current*
frontier size each level — the local SpMSpV data structure follows the
Fig-3 density rule, and an overflowing level retries with grown caps
instead of asserting. Pass ``prod_cap``/``out_cap`` only to override.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import (BOOLEAN, DistSpMat, DistSpVec, DistVec,
                    transpose_spvec_layout)
from ..core.matops import spvec_mask, spvec_nnz, vec_scatter_spvec
from ..core.plan import plan_spmspv, spmspv as spmspv_planned


def bfs_levels(a: DistSpMat, source: int, *, mesh: Mesh,
               prod_cap: int | None = None, out_cap: int | None = None,
               max_iters: int | None = None) -> np.ndarray:
    """Return per-vertex BFS levels (-1 = unreachable) from ``source``.

    ``a`` is interpreted as directed adjacency with edges u→v for entry
    (v, u) — i.e. we multiply y = A x so neighbors of the frontier x appear
    in y (CombBLAS convention: use A^T for the usual orientation).
    """
    n = a.shape[0]
    grid = a.grid
    levels = DistVec.from_global(np.full(n, -1, np.int32), grid,
                                 layout="row", mesh=mesh)
    # frontier capacity: the planner's output cap for a worst-case frontier
    # (so pieces never truncate); explicit out_cap still wins
    fcap = out_cap or plan_spmspv(a, n, out_cap=out_cap).out_cap
    frontier = DistSpVec.from_global(np.array([source], np.int64),
                                     np.ones(1, np.bool_), n, grid,
                                     cap=fcap, layout="row", mesh=mesh)
    levels = vec_scatter_spvec(levels, frontier,
                               lambda cur, xv: jnp.zeros_like(cur))
    level = 0
    max_iters = max_iters or n
    while int(spvec_nnz(frontier)) > 0 and level < max_iters:
        level += 1
        fcol = transpose_spvec_layout(frontier, mesh=mesh)
        nxt, _plan = spmspv_planned(a, fcol, BOOLEAN, mesh=mesh,
                                    prod_cap=prod_cap, out_cap=out_cap)
        nxt = spvec_mask(nxt, levels, lambda xv, lv: lv < 0)
        levels = vec_scatter_spvec(
            levels, nxt, lambda cur, xv: jnp.full_like(cur, level))
        frontier = nxt
    return levels.to_global().astype(np.int32)
