"""Breadth-first search via masked SpMSpV (the original CombBLAS demo app).

Level-synchronous BFS: the frontier is a FullyDistSpVec, each step is one
SpMSpV over the boolean semiring with the visited (levels) vector pushed in
as a COMPLEMENT mask (§4.7): already-visited vertices are discarded inside
the local expansion — before the variant merges and the 'col' exchange —
instead of being generated, shipped, and thrown away by a post-hoc
piece-aligned filter. The planner additionally caps the output at the
unvisited count, so sort/merge volumes shrink as the search saturates (the
direction-optimizing payoff without the pull-side kernel).

Capacities are chosen by the planner (core/plan.py) from the *current*
frontier size each level — the local SpMSpV data structure follows the
Fig-3 density rule, and an overflowing level retries with grown caps
instead of asserting. Pass ``prod_cap``/``out_cap`` only to override.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import (BOOLEAN, DistSpMat, DistSpVec, DistVec,
                    transpose_spvec_layout)
from ..obs import recorder as _obs
from ..core.mask import vector_mask
from ..core.matops import spvec_nnz, vec_scatter_spvec
from ..core.plan import plan_spmspv, spmspv as spmspv_planned


def bfs_levels(a: DistSpMat, source: int, *, mesh: Mesh,
               prod_cap: int | None = None, out_cap: int | None = None,
               max_iters: int | None = None) -> np.ndarray:
    """Return per-vertex BFS levels (-1 = unreachable) from ``source``.

    ``a`` is interpreted as directed adjacency with edges u→v for entry
    (v, u) — i.e. we multiply y = A x so neighbors of the frontier x appear
    in y (CombBLAS convention: use A^T for the usual orientation).
    """
    n = a.shape[0]
    grid = a.grid
    levels = DistVec.from_global(np.full(n, -1, np.int32), grid,
                                 layout="row", mesh=mesh)
    # frontier capacity: the planner's output cap for a worst-case frontier
    # (so pieces never truncate); explicit out_cap still wins
    fcap = out_cap or plan_spmspv(a, n, out_cap=out_cap).out_cap
    frontier = DistSpVec.from_global(np.array([source], np.int64),
                                     np.ones(1, np.bool_), n, grid,
                                     cap=fcap, layout="row", mesh=mesh)
    levels = vec_scatter_spvec(levels, frontier,
                               lambda cur, xv: jnp.zeros_like(cur))
    level = 0
    max_iters = max_iters or n
    while int(spvec_nnz(frontier)) > 0 and level < max_iters:
        level += 1
        with _obs.span("bfs.level", level=level,
                       frontier_nnz=int(spvec_nnz(frontier))):
            fcol = transpose_spvec_layout(frontier, mesh=mesh)
            # visited vertices (level >= 0) as a complement mask: the fused
            # kernel emits ONLY unvisited neighbors — no post-filter pass
            visited = vector_mask(levels, pred=lambda lv: lv >= 0,
                                  complement=True)
            nxt, _plan = spmspv_planned(a, fcol, BOOLEAN, mesh=mesh,
                                        mask=visited,
                                        prod_cap=prod_cap, out_cap=out_cap)
            levels = vec_scatter_spvec(
                levels, nxt, lambda cur, xv: jnp.full_like(cur, level))
            frontier = nxt
    return levels.to_global().astype(np.int32)
