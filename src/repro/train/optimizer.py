"""AdamW with global-norm clipping — sharded states (ZeRO-1/3 by plan).

Optimizer states inherit the parameter PartitionSpecs, so FSDP params get
fully sharded m/v for free (ZeRO-3); with params replicated over 'data' the
same code is ZeRO-1 (states sharded, params gathered by GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(m=jax.tree.map(zeros, params),
                v=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * \
        0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:       # no weight decay on norms/biases/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, dict(m=new_m, v=new_v, step=step), \
        dict(grad_norm=gnorm, lr=lr)
