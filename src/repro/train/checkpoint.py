"""Mesh-independent checkpointing (fault tolerance + elastic scaling).

Format: one directory per step —
    step_000123.tmp/…  →  atomic rename →  step_000123/
      manifest.json    tree structure, shapes, dtypes, step
      NNN.npy          one file per leaf, FULL (unsharded) logical array

Because leaves are stored logically (not per-shard), restore can target ANY
mesh: pass `specs`+`mesh` and each leaf is device_put straight into its new
sharding — this is the elastic-scaling path (tested in
tests/test_checkpoint.py by saving from one mesh shape and restoring onto
another). Production note (DESIGN.md §8): at 1000+ nodes the same manifest
format fronts a per-shard ocdbt-style store; the API here is the contract.

Durability: writes go to a ``.tmp`` directory, fsync'd, then renamed —
a crash mid-save never corrupts the latest complete checkpoint. ``keep``
old checkpoints are retained (default 3).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves = _flatten_with_paths(tree)
    manifest = dict(step=step, leaves=[])
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)          # gathers across devices
        fname = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(dict(path=p, file=fname,
                                       shape=list(arr.shape),
                                       dtype=str(arr.dtype)))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(d[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, *, step: int | None = None,
                       mesh=None, specs: Any = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With mesh+specs, leaves are placed sharded —
    onto ANY mesh shape (elastic restart)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, like_leaves = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    spec_leaves = jax.tree.leaves(specs) if specs is not None else \
        [None] * len(like_leaves)
    out_leaves = []
    for p, leaf, spec in zip(paths, like_leaves, spec_leaves):
        e = by_path[p]
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs "
                             f"{leaf.shape}")
        if mesh is not None and spec is not None:
            arr = jax.device_put(arr, jax.NamedSharding(mesh, spec))
        out_leaves.append(arr)
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, out_leaves), step
