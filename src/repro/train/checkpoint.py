"""Mesh-independent checkpointing (fault tolerance + elastic scaling).

Format: one directory per step —
    step_000123.tmp/…  →  atomic rename →  step_000123/
      manifest.json    tree structure, shapes, dtypes, per-leaf CRC32, step
      NNN.npy          one file per leaf, FULL (unsharded) logical array

Because leaves are stored logically (not per-shard), restore can target ANY
mesh: pass `specs`+`mesh` and each leaf is device_put straight into its new
sharding — this is the elastic-scaling path (tested in
tests/test_train.py::TestCheckpoint and end-to-end by
tests/elastic_scenario.py, which saves from one mesh shape and restores onto
another). Production note (DESIGN.md §8): at 1000+ nodes the same manifest
format fronts a per-shard ocdbt-style store; the API here is the contract.

Durability: writes go to a ``.tmp`` directory, fsync'd, then renamed —
a crash mid-save never corrupts the latest complete checkpoint. ``keep``
old checkpoints are retained (default 3).

Integrity (robust/): every leaf's bytes are CRC32-summed into the manifest
at save time and verified on restore. A corrupted, truncated, or missing
leaf raises :class:`CheckpointError` naming the leaf — and when the caller
asked for the *latest* step (``step=None``), restore falls back to the
previous retained checkpoint with a loud warning instead of dying on the
newest one (the ``checkpoint.leaf`` fault site exercises this).
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
import zlib
from typing import Any, Optional

import jax
import numpy as np

from ..obs import recorder as _obs
from ..robust import faults as _faults


class CheckpointError(ValueError):
    """A checkpoint leaf failed integrity verification on restore."""


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


@_obs.timed("ckpt.save")
def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves = _flatten_with_paths(tree)
    manifest = dict(step=step, leaves=[])
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)          # gathers across devices
        fname = f"{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        _faults.corrupt_file("checkpoint.leaf", fpath)
        manifest["leaves"].append(dict(path=p, file=fname,
                                       shape=list(arr.shape),
                                       dtype=str(arr.dtype),
                                       crc32=_leaf_crc(arr)))
        _obs.counter_add("ckpt.bytes_saved", arr.nbytes)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(d[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def _load_leaf(step_dir: str, entry: dict) -> np.ndarray:
    """Read + verify one manifest leaf; CheckpointError names the leaf."""
    fpath = os.path.join(step_dir, entry["file"])
    where = f"{fpath} (leaf {entry['path']!r})"
    if not os.path.exists(fpath):
        raise CheckpointError(f"missing checkpoint leaf {where}")
    try:
        arr = np.load(fpath)
    except Exception as err:
        raise CheckpointError(
            f"unreadable checkpoint leaf {where}: {err}") from err
    if tuple(arr.shape) != tuple(entry["shape"]) \
            or str(arr.dtype) != entry["dtype"]:
        raise CheckpointError(
            f"checkpoint leaf {where} shape/dtype drifted from manifest: "
            f"{arr.shape}/{arr.dtype} vs {entry['shape']}/{entry['dtype']}")
    if "crc32" in entry and _leaf_crc(arr) != entry["crc32"]:
        raise CheckpointError(
            f"checkpoint leaf {where} CRC32 mismatch "
            f"({_leaf_crc(arr):#010x} != manifest {entry['crc32']:#010x})")
    _obs.counter_add("ckpt.bytes_restored", arr.nbytes)
    return arr


def _candidate_steps(ckpt_dir: str, step: int | None):
    """Requested step only, or all retained steps newest-first."""
    if step is not None:
        return [step]
    steps = sorted(all_steps(ckpt_dir), reverse=True)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    return steps


@_obs.timed("ckpt.restore")
def restore_checkpoint(ckpt_dir: str, like: Any, *, step: int | None = None,
                       mesh=None, specs: Any = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With mesh+specs, leaves are placed sharded —
    onto ANY mesh shape (elastic restart).

    Every leaf is CRC32-verified against the manifest; a failed leaf raises
    :class:`CheckpointError` — unless ``step=None``, where restore falls
    back to the previous retained checkpoint (loudly)."""
    last_err: Exception | None = None
    for s in _candidate_steps(ckpt_dir, step):
        try:
            return _restore_one(ckpt_dir, s, like, mesh, specs), s
        except CheckpointError as err:
            if step is not None:
                raise
            warnings.warn(
                f"checkpoint step {s} failed verification ({err}); "
                "falling back to the previous retained checkpoint",
                RuntimeWarning, stacklevel=2)
            last_err = err
    raise last_err


def _restore_one(ckpt_dir: str, step: int, like, mesh, specs):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, like_leaves = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    spec_leaves = jax.tree.leaves(specs) if specs is not None else \
        [None] * len(like_leaves)
    out_leaves = []
    for p, leaf, spec in zip(paths, like_leaves, spec_leaves):
        arr = _load_leaf(d, by_path[p])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs "
                             f"{leaf.shape}")
        if mesh is not None and spec is not None:
            arr = jax.device_put(arr, jax.NamedSharding(mesh, spec))
        out_leaves.append(arr)
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, out_leaves)


@_obs.timed("ckpt.restore_flat")
def restore_flat(ckpt_dir: str, step: int | None = None):
    """Manifest-driven restore: ``({leaf_path: np.ndarray}, step)``.

    No ``like`` template — shapes come from the manifest, so callers whose
    state shapes change between steps (HipMCL's per-iteration re-planned
    capacities) can still resume. Same CRC verification and latest-step
    fallback as :func:`restore_checkpoint`."""
    last_err: Exception | None = None
    for s in _candidate_steps(ckpt_dir, step):
        d = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            return {e["path"]: _load_leaf(d, e)
                    for e in manifest["leaves"]}, s
        except CheckpointError as err:
            if step is not None:
                raise
            warnings.warn(
                f"checkpoint step {s} failed verification ({err}); "
                "falling back to the previous retained checkpoint",
                RuntimeWarning, stacklevel=2)
            last_err = err
    raise last_err
