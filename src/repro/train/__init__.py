"""repro.train — optimizer, train step, data pipeline, checkpointing."""
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .train_step import make_train_step
from .data import SyntheticLM, make_batch_specs
from .checkpoint import save_checkpoint, restore_checkpoint, latest_step
