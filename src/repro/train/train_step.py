"""Train-step factory: loss → grads (microbatched) → AdamW update.

Gradient accumulation runs as a lax.scan over microbatches with grads
reduced inside the scan (the per-microbatch reduce-scatter overlaps with
the next microbatch's compute under XLA's scheduler — the paper's
"overlap communication with computation", LM edition).

Optional gradient compression (dist/compression.py) quantizes or
sparsifies grads before the cross-pod reduction.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig, *, accum: int = 1,
                    compressor=None):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics).

    batch leaves have leading dim = global batch; with accum > 1 the batch
    is split into `accum` microbatches along axis 0.
    """

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), m

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(micro, (zero_g, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        if compressor is not None:
            grads = compressor(grads)
        new_params, new_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return new_params, new_state, dict(loss=loss, **metrics,
                                           **opt_metrics)

    return train_step
