"""Data pipeline: deterministic synthetic LM stream + background prefetch.

Determinism contract (fault tolerance, DESIGN.md §8): batch(step) is a pure
function of (seed, step, shape) — after restart, training resumes from the
checkpointed step and sees bitwise-identical data, with no pipeline state
to checkpoint beyond the step counter.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Markov-ish synthetic token stream (hash-mixed), deterministic."""

    def __init__(self, vocab: int, seq: int, batch: int, seed: int = 0):
        self.vocab, self.seq, self.batch, self.seed = vocab, seq, batch, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # mixture of a repeating motif + noise so loss visibly drops
        base = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        motif = (np.arange(self.seq + 1) * 7 + step % 13) % self.vocab
        use = rng.random((self.batch, self.seq + 1)) < 0.7
        toks = np.where(use, motif[None, :], base).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:].copy())

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering the host→device copy).

    Failure contract: an exception in ``source.batch_at`` is captured on the
    worker thread and re-raised in ``next()`` (after any already-prefetched
    batches are consumed) — never a silently dead worker with ``next()``
    blocking forever. ``close()`` joins the thread.
    """

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self.source = source
        self._stop = threading.Event()
        self._exc: BaseException | None = None
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                batch = self.source.batch_at(s)
            except BaseException as err:      # noqa: BLE001 — relayed, not
                self._exc = err               # swallowed: next() re-raises
                return
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=1.0)
                    s += 1
                    break
                except queue.Full:
                    continue

    def next(self) -> dict:
        # poll so a worker death surfaces instead of blocking forever;
        # batches queued before the failure are still delivered in order
        while True:
            try:
                return self.q.get(timeout=0.1)
            except queue.Empty:
                if self._exc is not None:
                    raise self._exc
                if not self.t.is_alive():
                    raise RuntimeError(
                        "Prefetcher worker thread died without queuing a "
                        "batch or recording an exception")

    def close(self, timeout: float = 5.0) -> bool:
        """Stop and join the worker; returns True when it actually exited.

        Draining once then joining isn't enough: the worker can re-fill the
        queue between the drain and its next ``put`` (the old behavior
        silently leaked the thread on join timeout). Keep draining while
        joining so a put()-blocked worker always sees the stop flag, and
        warn loudly if the thread is still alive at the deadline (a worker
        stuck inside ``source.batch_at`` — daemonized, so it won't block
        interpreter exit, but it still holds the source).
        """
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self.t.is_alive() and time.monotonic() < deadline:
            # drain so a put()-blocked worker sees the stop flag promptly
            try:
                while True:
                    self.q.get_nowait()
            except queue.Empty:
                pass
            self.t.join(timeout=0.05)
        if self.t.is_alive():
            warnings.warn(
                f"Prefetcher.close(): worker thread still alive after "
                f"{timeout:.1f}s — it is likely blocked inside "
                "source.batch_at. The daemon thread will not block exit, "
                "but it may keep consuming the source.",
                RuntimeWarning, stacklevel=2)
            return False
        return True


def make_batch_specs(cfg, shape: dict, plan=None):
    """ShapeDtypeStructs for a training batch of the given arch/shape."""
    B, S = shape["batch"], shape["seq"]
    D = cfg.d_model
    i32 = jnp.int32
    if cfg.kind == "encoder":
        return dict(features=jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16),
                    mask=jax.ShapeDtypeStruct((B, S), jnp.bool_),
                    targets=jax.ShapeDtypeStruct((B, S), i32))
    batch = dict(tokens=jax.ShapeDtypeStruct((B, S), i32),
                 labels=jax.ShapeDtypeStruct((B, S), i32))
    if cfg.frontend == "vision_patches":
        batch["vision_embeds"] = jax.ShapeDtypeStruct((B, max(S // 4, 1), D),
                                                      jnp.bfloat16)
        batch["vision_mask"] = jax.ShapeDtypeStruct((B, S), jnp.bool_)
        batch["pos3"] = jax.ShapeDtypeStruct((3, B, S), i32)
    return batch


def synthetic_batch(cfg, shape: dict, seed: int = 0):
    """Concrete random batch matching make_batch_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    B, S = shape["batch"], shape["seq"]
    D = cfg.d_model
    if cfg.kind == "encoder":
        return dict(
            features=jnp.asarray(rng.standard_normal((B, S, D)),
                                 jnp.bfloat16),
            mask=jnp.asarray(rng.random((B, S)) < cfg.mask_prob),
            targets=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32))
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32))
    if cfg.frontend == "vision_patches":
        T = max(S // 4, 1)
        vmask = np.zeros((B, S), bool)
        vmask[:, :T] = True
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, T, D)), jnp.bfloat16)
        batch["vision_mask"] = jnp.asarray(vmask)
        pos3 = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        batch["pos3"] = jnp.asarray(pos3)
    return batch
