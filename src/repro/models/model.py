"""Model assembly: parameter init/specs + forward for all four families.

Layer stacking: layers are grouped into *periods* — the smallest repeating
signature of (mixer kind, is_moe) — and parameters are stacked over
period-repeats so the whole stack lowers as ONE lax.scan (compile time and
HLO size stay O(period), not O(L); remat wraps each period).

  decoder/encoder : period 1 (uniform layers)
  deepseek-v2-lite: period 1 (all-MoE per the assigned config)
  jamba           : period 8 (attn at offset 4, MoE every 2nd layer)
  mamba2          : period 1

Params are plain nested dicts of jnp arrays; init is deterministic in
(seed, path). ``abstract=True`` gives ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L

Array = jax.Array


def _dtype(name):
    return dict(bfloat16=jnp.bfloat16, float32=jnp.float32,
                float16=jnp.float16)[name]


def vocab_padded(cfg: ModelConfig, mult: int = 256) -> int:
    return -(-cfg.vocab // mult) * mult


def period_of(cfg: ModelConfig) -> int:
    sig = list(zip(cfg.layer_kinds(), cfg.layer_moe()))
    for p in range(1, cfg.n_layers + 1):
        if cfg.n_layers % p:
            continue
        if all(sig[i] == sig[i % p] for i in range(cfg.n_layers)):
            return p
    return cfg.n_layers


def experts_padded(cfg: ModelConfig, mult: int = 16) -> int:
    return -(-cfg.n_experts // mult) * mult if cfg.is_moe else 0


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------

def _layer_param_shapes(cfg: ModelConfig, kind: str, moe: bool):
    """Shapes for one layer position (unstacked)."""
    D = cfg.d_model
    shapes: dict[str, tuple] = {"ln1": (D,)}
    if kind == "attn":
        if cfg.use_mla:
            dq = cfg.qk_nope_dim + cfg.qk_rope_dim
            shapes.update(
                wq=(D, cfg.n_heads * dq),
                w_dkv=(D, cfg.kv_lora_rank + cfg.qk_rope_dim),
                kv_ln=(cfg.kv_lora_rank,),
                w_ukv=(cfg.kv_lora_rank,
                       cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)),
                wo=(cfg.n_heads * cfg.v_head_dim, D))
        else:
            hd = cfg.hd
            shapes.update(wq=(D, cfg.n_heads * hd),
                          wk=(D, cfg.n_kv_heads * hd),
                          wv=(D, cfg.n_kv_heads * hd),
                          wo=(cfg.n_heads * hd, D))
            if cfg.qkv_bias:
                shapes.update(bq=(cfg.n_heads * hd,),
                              bk=(cfg.n_kv_heads * hd,),
                              bv=(cfg.n_kv_heads * hd,))
    else:  # mamba
        Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        shapes.update(in_z=(D, Din), in_xbc=(D, Din + 2 * N), in_dt=(D, H),
                      conv_w=(cfg.d_conv, Din + 2 * N),
                      dt_bias=(H,), A_log=(H,), D_skip=(H,),
                      out_proj=(Din, D))
    has_ffn = (cfg.kind != "ssm")
    if has_ffn:
        shapes["ln2"] = (D,)
        if moe:
            E = experts_padded(cfg)
            F = cfg.d_ff
            shapes.update(router=(D, E),
                          we_g=(E, D, F), we_1=(E, D, F), we_2=(E, F, D))
            if cfg.n_shared_experts:
                Ns = cfg.n_shared_experts
                shapes.update(ws_g=(Ns, D, F), ws_1=(Ns, D, F),
                              ws_2=(Ns, F, D))
        else:
            F = cfg.d_ff
            if cfg.mlp_act == "gelu":
                shapes.update(w1=(D, F), w2=(F, D))
            else:
                shapes.update(wg=(D, F), w1=(D, F), w2=(F, D))
    return shapes


def param_shapes(cfg: ModelConfig):
    """Full model parameter shape tree (stacked periods)."""
    Vp = vocab_padded(cfg)
    D = cfg.d_model
    period = period_of(cfg)
    reps = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]
    moes = cfg.layer_moe()[:period]
    blocks = {}
    for pos in range(period):
        lshapes = _layer_param_shapes(cfg, kinds[pos], moes[pos])
        blocks[f"pos{pos}"] = {k: (reps,) + v for k, v in lshapes.items()}
    tree = dict(embed=(Vp, D), final_norm=(D,), blocks=blocks)
    if not cfg.tie_embeddings:
        tree["lm_head"] = (Vp, D)
    if cfg.frontend == "vision_patches":
        tree["vision_proj"] = (D, D)     # stub projector for patch embeds
    if cfg.frontend == "audio_frames":
        tree["frame_proj"] = (D, D)
    return tree


def _init_one(key, path: str, shape, cfg: ModelConfig):
    pdt = _dtype(cfg.param_dtype)
    name = path.split("/")[-1]
    if name.startswith("ln") or name in ("final_norm", "kv_ln"):
        return jnp.ones(shape, pdt)
    if name == "A_log":
        return jnp.log(jnp.linspace(1.0, 16.0, shape[-1], dtype=jnp.float32)
                       ).astype(pdt) * jnp.ones(shape, pdt)
    if name == "dt_bias":
        # softplus^-1 of dt in [1e-3, 1e-1], log-spaced
        dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1),
                                  shape[-1], dtype=jnp.float32))
        inv = jnp.log(jnp.expm1(dt))
        return (inv * jnp.ones(shape, jnp.float32)).astype(pdt)
    if name == "D_skip":
        return jnp.ones(shape, pdt)
    if name.startswith("b"):
        return jnp.zeros(shape, pdt)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 0.02 if name in ("embed", "lm_head") else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(pdt)


def init_params(cfg: ModelConfig, seed: int = 0, abstract: bool = False):
    shapes = param_shapes(cfg)
    pdt = _dtype(cfg.param_dtype)

    def build(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out[k] = build(v, path)
            else:
                if abstract:
                    out[k] = jax.ShapeDtypeStruct(v, pdt)
                else:
                    # stable digest of the path: Python's hash() is salted
                    # per process (PYTHONHASHSEED), which would initialize
                    # different params on different hosts
                    key = jax.random.fold_in(
                        jax.random.PRNGKey(seed),
                        zlib.crc32(path.encode()) & 0x7FFFFFFF)
                    out[k] = _init_one(key, path, v, cfg)
        return out

    return build(shapes)


def init_param_specs(cfg: ModelConfig, plan) -> Any:
    """PartitionSpec tree matching param_shapes (see dist/shardings.py)."""
    from ..dist.shardings import spec_for_param
    shapes = param_shapes(cfg)

    def build(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else k
            out[k] = build(v, path) if isinstance(v, dict) \
                else spec_for_param(path, v, cfg, plan)
        return out

    return build(shapes)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    plan: Any = None                     # ShardingPlan or None
    scan_unroll: bool = False            # unroll the layer scan (dry-run
    # depth probes: exact cost analysis needs while-free HLO)
    cast_early: bool = False             # cast params to the compute dtype
    # BEFORE the sharded-use boundary, so FSDP all-gathers and TP
    # collectives move bf16 instead of f32 (§Perf iteration 1)

    # ---------------- embedding / frontend ----------------
    def embed(self, params, batch):
        cfg = self.cfg
        adt = _dtype(cfg.dtype)
        if cfg.frontend == "audio_frames":
            x = batch["features"].astype(adt) @ \
                params["frame_proj"].astype(adt)
            return x
        tok = batch["tokens"]
        x = jnp.take(params["embed"], tok, axis=0).astype(adt)
        if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
            # (decode steps are text-only — vision enters at prefill)
            ve = batch["vision_embeds"].astype(adt) @ \
                params["vision_proj"].astype(adt)
            # scatter patch embeddings over the marked positions: the stub
            # places patch t at the t-th True position of vision_mask
            B, S, D = x.shape
            T = ve.shape[1]
            vm = batch["vision_mask"]
            rank = jnp.cumsum(vm, axis=1) - 1            # (B, S)
            take = jnp.clip(rank, 0, T - 1)
            ve_at = jnp.take_along_axis(ve, take[..., None], axis=1)
            x = jnp.where(vm[..., None], ve_at, x)
        return x

    # ---------------- one layer ----------------
    _KEEP_F32 = ("A_log", "dt_bias", "D_skip", "ln1", "ln2", "kv_ln")

    def _cast_params(self, p):
        adt = _dtype(self.cfg.dtype)
        return {k: v if k in self._KEEP_F32 else v.astype(adt)
                for k, v in p.items()}

    def _mixer(self, x, p, kind, pos, pos3, cache):
        cfg = self.cfg
        if kind == "attn":
            if cfg.use_mla:
                return L.mla_block(x, p, cfg, pos, cache=cache)
            return L.gqa_block(x, p, cfg, pos, cache=cache, pos3=pos3)
        return L.mamba_block(x, p, cfg, cache=cache)

    def _layer(self, x, p, kind, moe, pos, pos3, cache):
        cfg = self.cfg
        p = self._cast_params(p)
        h = L.rmsnorm(x, p["ln1"], cfg.rms_eps)
        mix, new_cache = self._mixer(h, p, kind, pos, pos3, cache)
        x = x + mix.astype(x.dtype)
        aux = jnp.zeros((), jnp.float32)
        if "ln2" in p:
            h = L.rmsnorm(x, p["ln2"], cfg.rms_eps)
            if moe:
                if self.plan is not None and self.plan.moe_ep and \
                        self.plan.mesh is not None:
                    ff, aux = L.moe_block_ep(h, p, cfg, self.plan)
                else:
                    ff, aux = L.moe_block(
                        h, p, cfg,
                        ep_spec=self.plan.ep_spec() if self.plan else None)
            else:
                ff = L.mlp_block(h, p, cfg)
            x = x + ff.astype(x.dtype)
        if self.plan is not None:
            x = L.constrain(x, self.plan.act_spec())
        return x, aux, new_cache

    # ---------------- full stack ----------------
    def forward(self, params, batch, *, caches=None, remat=True):
        """Returns (logits, aux_loss, new_caches)."""
        cfg = self.cfg
        unroll = self.scan_unroll
        if self.cast_early:
            adt = _dtype(cfg.dtype)
            params = dict(params)
            params["blocks"] = {
                pos: {k: (v if k in self._KEEP_F32 else v.astype(adt))
                      for k, v in blk.items()}
                for pos, blk in params["blocks"].items()}
            for k in ("embed", "lm_head", "vision_proj", "frame_proj"):
                if k in params:
                    params[k] = params[k].astype(adt)
        period = period_of(cfg)
        reps = cfg.n_layers // period
        kinds = cfg.layer_kinds()[:period]
        moes = cfg.layer_moe()[:period]
        x = self.embed(params, batch)
        if self.plan is not None:
            x = L.constrain(x, self.plan.act_spec())
        B, S, D = x.shape
        offset = batch.get("offset", None)
        if offset is None:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            pos = offset[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
        pos3 = batch.get("pos3", None)

        def superblock(x, blk_params, blk_caches):
            aux_total = jnp.zeros((), jnp.float32)
            new_caches = {}
            for i, (kind, moe) in enumerate(zip(kinds, moes)):
                c = blk_caches.get(f"pos{i}") if blk_caches else None
                x, aux, nc = self._layer(x, blk_params[f"pos{i}"], kind, moe,
                                         pos, pos3, c)
                aux_total = aux_total + aux
                if nc is not None:
                    new_caches[f"pos{i}"] = nc
            return x, aux_total, new_caches

        if caches is None:
            def scan_body(x, blk_params):
                fn = superblock
                if remat:
                    fn = jax.checkpoint(
                        lambda xx, pp: superblock(xx, pp, None)[:2],
                        policy=jax.checkpoint_policies.nothing_saveable)
                    x, aux = fn(x, blk_params)
                else:
                    x, aux, _ = superblock(x, blk_params, None)
                return x, aux

            x, auxs = jax.lax.scan(scan_body, x, params["blocks"],
                                   length=reps, unroll=reps if unroll else 1)
            aux = jnp.sum(auxs)
            new_caches = None
        else:
            def scan_body(x, xs):
                blk_params, blk_caches = xs
                x, aux, ncs = superblock(x, blk_params, blk_caches)
                return x, (aux, ncs)

            x, (auxs, new_caches) = jax.lax.scan(
                scan_body, x, (params["blocks"], caches), length=reps,
                unroll=reps if unroll else 1)
            aux = jnp.sum(auxs)

        x = L.rmsnorm(x, params["final_norm"], cfg.rms_eps)
        head = params.get("lm_head", params["embed"])
        logits = x @ head.T.astype(x.dtype)
        if self.plan is not None:
            logits = L.constrain(logits, self.plan.logits_spec())
        return logits, aux, new_caches

    # ---------------- losses ----------------
    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch)
        Vp = logits.shape[-1]
        if cfg.kind == "encoder":
            labels = batch["targets"]
            mask = batch["mask"].astype(jnp.float32)
        else:
            labels = batch["labels"]
            mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        lz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
        nll = (lz - gold) * mask
        ntok = jnp.maximum(mask.sum(), 1.0)
        return nll.sum() / ntok + aux, dict(
            nll=nll.sum() / ntok, aux=aux, ntok=ntok)

    # ---------------- kv / state caches ----------------
    def init_cache(self, batch_size: int, max_len: int, abstract=False,
                   dtype=None):
        """Stacked cache pytree matching forward(caches=...) layout."""
        cfg = self.cfg
        dt = dtype or _dtype(cfg.dtype)
        period = period_of(cfg)
        reps = cfg.n_layers // period
        kinds = cfg.layer_kinds()[:period]

        def mk(shape, dtyp=None):
            d = dtyp or dt
            if abstract:
                return jax.ShapeDtypeStruct(shape, d)
            return jnp.zeros(shape, d)

        caches = {}
        for i, kind in enumerate(kinds):
            if kind == "attn":
                if cfg.use_mla:
                    c = dict(
                        c_kv=mk((reps, batch_size, max_len,
                                 cfg.kv_lora_rank)),
                        k_rope=mk((reps, batch_size, max_len, 1,
                                   cfg.qk_rope_dim)),
                        offset=mk((reps,), jnp.int32))
                else:
                    c = dict(
                        k=mk((reps, batch_size, max_len, cfg.n_kv_heads,
                              cfg.hd)),
                        v=mk((reps, batch_size, max_len, cfg.n_kv_heads,
                              cfg.hd)),
                        offset=mk((reps,), jnp.int32))
            else:
                c = dict(
                    conv=mk((reps, batch_size, cfg.d_conv - 1,
                             cfg.d_inner + 2 * cfg.ssm_state)),
                    state=mk((reps, batch_size, cfg.ssm_heads,
                              cfg.ssm_headdim, cfg.ssm_state), jnp.float32))
            caches[f"pos{i}"] = c
        return caches
