"""ModelConfig — a single dataclass describing every supported architecture.

Families (``kind``):
  'decoder'  causal LM: GQA/MLA attention + (dense | MoE) MLP   [most archs]
  'encoder'  bidirectional encoder (HuBERT): masked-unit prediction
  'ssm'      attention-free Mamba2 (SSD)
  'hybrid'   Jamba: periodic attention in a Mamba stack, MoE interleave

Every field is explicit so configs/<arch>.py files read like the paper
tables they came from.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                       # decoder | encoder | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int

    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False          # qwen2
    rope_theta: float = 1e6
    mrope_sections: Optional[tuple[int, ...]] = None   # qwen2-vl M-RoPE
    causal: bool = True

    # ---- MLA (deepseek-v2) ----
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0            # 0 = full-rank q projection
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # ---- MLP / MoE ----
    d_ff: int = 0                   # dense MLP width (per expert for MoE)
    mlp_act: str = "silu"           # silu (swiglu) | gelu (hubert)
    n_experts: int = 0              # routed experts (0 = dense)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1              # MoE layer every k layers (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ---- Mamba2 / SSD ----
    ssm_state: int = 0              # N
    ssm_headdim: int = 64           # P
    ssm_expand: int = 2
    ssm_chunk: int = 128
    d_conv: int = 4
    attn_period: int = 0            # hybrid: one attention layer每 period
    attn_offset: int = 0            # index within the period

    # ---- encoder (hubert) ----
    mask_prob: float = 0.08

    # ---- numerics / norm ----
    rms_eps: float = 1e-6
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = False

    # ---- frontend stubs ----
    frontend: Optional[str] = None  # None | 'audio_frames' | 'vision_patches'

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:       # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> list[str]:
        """Per-layer kind: 'attn' | 'mamba' for the mixer part."""
        if self.kind in ("decoder", "encoder"):
            return ["attn"] * self.n_layers
        if self.kind == "ssm":
            return ["mamba"] * self.n_layers
        out = []
        for i in range(self.n_layers):
            if self.attn_period and i % self.attn_period == self.attn_offset:
                out.append("attn")
            else:
                out.append("mamba")
        return out

    def layer_moe(self) -> list[bool]:
        if not self.is_moe:
            return [False] * self.n_layers
        return [(i % self.moe_every) == (self.moe_every - 1)
                if self.moe_every > 1 else True
                for i in range(self.n_layers)]

    def validate(self):
        assert self.kind in ("decoder", "encoder", "ssm", "hybrid")
        if self.kind in ("decoder", "encoder"):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.kind == "hybrid":
            assert self.attn_period > 0
        if self.is_moe:
            assert 0 < self.top_k <= self.n_experts
        return self

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
    D, V = cfg.d_model, cfg.vocab
    total = V * D                       # embedding
    if not cfg.tie_embeddings:
        total += V * D                  # lm head
    kinds = cfg.layer_kinds()
    moes = cfg.layer_moe()
    for kind, moe in zip(kinds, moes):
        total += 2 * D                  # norms
        if kind == "attn":
            if cfg.use_mla:
                qd = cfg.qk_rope_dim + cfg.qk_nope_dim
                total += D * cfg.n_heads * qd                 # q proj
                total += D * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                total += cfg.kv_lora_rank * cfg.n_heads * \
                    (cfg.qk_nope_dim + cfg.v_head_dim)
                total += cfg.n_heads * cfg.v_head_dim * D     # o proj
            else:
                hd = cfg.hd
                total += D * cfg.n_heads * hd                 # wq
                total += 2 * D * cfg.n_kv_heads * hd          # wk, wv
                total += cfg.n_heads * hd * D                 # wo
                if cfg.qkv_bias:
                    total += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        else:
            Din, N = cfg.d_inner, cfg.ssm_state
            H = cfg.ssm_heads
            total += D * (2 * Din + 2 * N + H)                # in_proj
            total += cfg.d_conv * (Din + 2 * N)               # conv
            total += Din * D                                  # out_proj
            total += 2 * H + Din                              # A, dt_bias, Dskip
        if moe:
            total += D * cfg.n_experts                        # router
            total += cfg.n_experts * 3 * D * cfg.d_ff
            total += cfg.n_shared_experts * 3 * D * cfg.d_ff
        elif kind == "attn" or cfg.kind != "ssm":
            if cfg.d_ff:
                mult = 3 if cfg.mlp_act == "silu" else 2
                total += mult * D * cfg.d_ff
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) parameters — MoE counts top_k + shared only."""
    if not cfg.is_moe:
        return param_count(cfg)
    dense = dataclasses.replace(cfg, n_experts=0, n_shared_experts=0)
    base = param_count(dense)
    # subtract the dense-MLP layers counted for moe positions, add active moe
    D = cfg.d_model
    for moe in cfg.layer_moe():
        if moe:
            base -= 3 * D * cfg.d_ff * (1 if cfg.d_ff else 0)
            base += D * cfg.n_experts          # router
            base += (cfg.top_k + cfg.n_shared_experts) * 3 * D * cfg.d_ff
    return base
