"""repro.models — composable LM architectures (pillar B, DESIGN.md §5)."""
from .config import ModelConfig
from .model import Model, init_params, init_param_specs
