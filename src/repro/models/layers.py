"""Model layers: norms, RoPE/M-RoPE, GQA + MLA attention, MLP, MoE, Mamba2.

Pure functions over explicit param pytrees (no framework). Sharding is
GSPMD-driven: ``shard_activations`` inserts with_sharding_constraint at
block boundaries; parameter shardings live in repro/dist/shardings.py.

MoE dispatch is the paper's technique as a first-class feature
(DESIGN.md §5): the router's top-k choices form a block-sparse
tokens→(expert, slot) assignment computed with the same radix-bucketing
used by the sparse library's all-to-all routing; expert FFNs are a
block-diagonal SpMM (grouped matmul, kernels/bsr_spmm.py on TPU).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

Array = jax.Array


def constrain(x, spec: Optional[P]):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        return x                         # outside jit/mesh context


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

def rmsnorm(x: Array, w: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    nrm = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (nrm * w.astype(jnp.float32)).astype(dt)


def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x: (B, S, H, hd); pos: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, pos3: Array, theta: float,
                sections: tuple[int, ...]) -> Array:
    """Qwen2-VL multimodal RoPE. pos3: (3, B, S) (t, h, w) positions;
    ``sections`` splits the hd/2 frequency slots across the three axes."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang_all = pos3[..., None].astype(jnp.float32) * freqs  # (3, B, S, hd/2)
    # pick which of t/h/w drives each frequency slot
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=hd // 2)    # (hd/2,)
    ang = jnp.squeeze(
        jnp.take_along_axis(ang_all.transpose(1, 2, 3, 0),
                            sec_id[None, None, :, None], axis=-1), -1)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA), chunked-causal softmax (flash-style, pure JAX)
# --------------------------------------------------------------------------

def _chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                       kv_chunk: int = 1024) -> Array:
    """Online-softmax attention, O(S·chunk) memory (B, S, H, hd inputs).

    The TPU production path is kernels/flash_attention.py; this pure-JAX
    twin keeps the same blocking so the dry-run HLO reflects the real
    memory behavior. Block-causal: key blocks strictly above the diagonal
    are skipped inside the scan via masking of the running maximum.
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[-1]                 # may differ from hd (MLA: 192 vs 128)
    rep = H // kvh
    scale = 1.0 / np.sqrt(hd)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-S // q_chunk)
    nk = -(-Skv // kv_chunk)
    q = q.reshape(B, nq, q_chunk, H, hd)

    def q_block(qi, qc):
        # qc: (B, q_chunk, H, hd)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, ki):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            ks = jnp.repeat(ks, rep, axis=2)
            vs = jnp.repeat(vs, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, ks,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.where(jnp.isinf(m), jnp.zeros_like(m),
                             jnp.exp(m - m_safe))
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, qc, H, hd)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), q.transpose(1, 0, 2, 3, 4)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)


def attention(q, k, v, *, causal: bool, chunked: bool = None) -> Array:
    """q: (B,S,H,hd), k/v: (B,Skv,KVH,hd) → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Skv, kvh = k.shape[1], k.shape[2]
    if chunked is None:
        chunked = S * Skv > 4096 * 4096
    if chunked and S > 1:
        return _chunked_attention(q, k, v, causal=causal)
    rep = H // kvh
    ks = jnp.repeat(k, rep, axis=2)
    vs = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, ks,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if causal and S > 1:
        mask = jnp.tril(jnp.ones((S, Skv), bool), k=Skv - S)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vs)
    return out.astype(q.dtype)


def gqa_block(x, params, cfg: ModelConfig, pos, *, cache=None,
              pos3=None) -> tuple[Array, Any]:
    """GQA attention sublayer. cache: None (train/prefill) or
    dict(k, v, offset) for decode. Returns (out, new_cache)."""
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KVH, hd)
    v = v.reshape(B, S, KVH, hd)
    if cfg.mrope_sections is not None and pos3 is not None:
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 cache["offset"], axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 cache["offset"], axis=1)
        new_cache = dict(k=ck, v=cv, offset=cache["offset"] + S)
        k, v = ck, cv
        # decode attends to all cached positions < offset+S
        out = _decode_attention(q, k, v, cache["offset"] + S)
    else:
        out = attention(q, k, v, causal=cfg.causal)
    out = out.reshape(B, S, H * hd) @ params["wo"]
    return out, new_cache


def _decode_attention(q, k, v, valid_len):
    """Query attention over a (possibly padded) cache, causal w.r.t. the
    absolute query positions (prefill chunks stay causal).

    Grouped-GQA formulation: query heads are reshaped to (kv_head, group)
    and contracted against the UN-replicated cache — no jnp.repeat
    materialization (8× KV traffic for 64q/8kv), and with the cache
    sequence-sharded the softmax reductions cross shards as tiny
    all-reduces instead of cache all-gathers (§Perf cell C).
    """
    B, S, H, hd = q.shape
    kvh = k.shape[2]
    rep = H // kvh
    dv = v.shape[-1]
    qg = q.reshape(B, S, kvh, rep, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    kpos = jnp.arange(k.shape[1])
    qpos = valid_len - S + jnp.arange(S)          # absolute query positions
    mask = kpos[None, :] <= qpos[:, None]         # (S, Skv)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, S, kvh * rep, dv).astype(q.dtype)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------

def mla_block(x, params, cfg: ModelConfig, pos, *, cache=None):
    """MLA: KV compressed to a kv_lora_rank latent (+ shared rope key).

    Cache stores only (c_kv, k_rope): the paper-matching memory win
    (kv_lora 512 + rope 64 per token instead of 2·H·hd).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dq = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ params["wq"]).reshape(B, S, H, dq)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    # compressed kv latent + shared rope key
    ckv = x @ params["w_dkv"]                       # (B,S,lora+rope)
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, params["kv_ln"], cfg.rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache["offset"],
            axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            cache["offset"], axis=1)
        new_cache = dict(c_kv=c_kv, k_rope=k_rope,
                         offset=cache["offset"] + S)
        valid = cache["offset"] + S
    else:
        valid = None
    # up-project keys/values from the latent
    wkv = params["w_ukv"].reshape(cfg.kv_lora_rank, H,
                                  cfg.qk_nope_dim + cfg.v_head_dim)
    kv = jnp.einsum("bsl,lhe->bshe", c_kv, wkv)
    k_nope, vv = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] +
                                  (cfg.qk_rope_dim,))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    if cache is not None:
        out = _decode_attention(q_full, k_full, vv, valid)
    else:
        out = attention(q_full, k_full, vv, causal=cfg.causal)
    out = out.reshape(B, S, H * cfg.v_head_dim) @ params["wo"]
    return out, new_cache


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------

def mlp_block(x, params, cfg: ModelConfig) -> Array:
    if cfg.mlp_act == "gelu":
        return jax.nn.gelu(x @ params["w1"]) @ params["w2"]
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["w1"])) @ params["w2"]


def _expert_ffn(xe, wg, w1, w2):
    """xe: (E, C, D); w*: (E, D, F)/(E, F, D) — block-diagonal grouped
    matmul (the bsr_spmm pattern)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
        jnp.einsum("ecd,edf->ecf", xe, w1)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_block(x, params, cfg: ModelConfig, *, ep_spec: Optional[P] = None):
    """Top-k MoE with capacity-bounded sort-based dispatch (semiring-SpMM
    formulation of the paper's machinery — DESIGN.md §5).

    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    T = B * S
    E = params["router"].shape[-1]      # padded for EP divisibility
    K = cfg.top_k
    xt = x.reshape(T, D)
    logits = (xt @ params["router"]).astype(jnp.float32)     # (T, E)
    if E > cfg.n_experts:               # mask padding experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    # aux load-balancing loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32)) / (T * K)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- dispatch: the sparse tokens×experts matrix, radix-bucketed ----
    C = int(cfg.capacity_factor * T * K / E + 0.999)
    C = max(8, min(C, T))
    flat_e = gate_idx.reshape(-1)                            # (T·K,)
    order = jnp.argsort(flat_e, stable=True)                 # bucket by expert
    e_sorted = flat_e[order]
    seg = jnp.searchsorted(e_sorted, jnp.arange(E + 1)).astype(jnp.int32)
    within = jnp.arange(T * K, dtype=jnp.int32) - e_sorted_start(seg, e_sorted)
    keep = within < C
    slot = jnp.where(keep, e_sorted * C + within, E * C)     # OOB drop
    tok_of = order // K
    xe = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        xt[tok_of], mode="drop").reshape(E, C, D)
    gates = jnp.zeros((E * C,), jnp.float32).at[slot].set(
        gate_vals.reshape(-1)[order], mode="drop").reshape(E, C)
    xe = constrain(xe, ep_spec)
    ye = _expert_ffn(xe, params["we_g"], params["we_1"], params["we_2"])
    ye = constrain(ye, ep_spec)
    # combine: y[t] += gate · ye[slot(t)]  (the transpose SpMM)
    ye_flat = (ye.reshape(E * C, D) *
               gates.reshape(E * C, 1).astype(ye.dtype))
    contrib = ye_flat[jnp.clip(slot, 0, E * C - 1)]          # (T·K, D)
    contrib = jnp.where(keep[:, None], contrib, 0)
    y = jnp.zeros((T, D), ye.dtype).at[tok_of].add(contrib)
    if cfg.n_shared_experts:
        y = y + _shared_experts(xt, params).astype(y.dtype)
    return y.reshape(B, S, D).astype(x.dtype), aux


def _shared_experts(xt, params):
    """Σ_s FFN_s(x) == ONE dense FFN with F-concatenated weights — a plain
    column→row-parallel pair that GSPMD shards like any MLP (a per-expert
    einsum over a broadcast token axis defeats the partitioner and
    replicates all tokens — measured in §Perf cell A iteration 3)."""
    Ns, D, F = params["ws_g"].shape
    wsg = params["ws_g"].transpose(1, 0, 2).reshape(D, Ns * F)
    ws1 = params["ws_1"].transpose(1, 0, 2).reshape(D, Ns * F)
    ws2 = params["ws_2"].reshape(Ns * F, D)
    h = jax.nn.silu(xt @ wsg) * (xt @ ws1)
    return h @ ws2


def e_sorted_start(seg, e_sorted):
    return seg[jnp.clip(e_sorted, 0, seg.shape[0] - 2)]


def moe_block_ep(x, params, cfg: ModelConfig, plan) -> tuple[Array, Array]:
    """Expert-parallel MoE via shard_map (the paper's technique, first
    class — DESIGN.md §5).

    The GSPMD formulation (moe_block) scatters tokens into an (E, C, D)
    buffer with data-dependent indices; the partitioner cannot shard a
    data-dependent scatter and replicates the dispatch buffers
    (≈E·C·D bytes of all-gather per layer — measured in §Perf cell A).
    Here dispatch is an explicit bulk-synchronous exchange, exactly the
    sparse library's routing discipline:

      per dp-shard: top-k route → radix-bucket local tokens by expert
      (tokens×experts sparse matrix, fixed capacity) → all-to-all over the
      TP axis (experts are sharded there) → local grouped FFN (the
      block-diagonal SpMM / bsr_spmm pattern) → reverse all-to-all →
      weighted combine. On the multi-pod mesh the a2a stays pod-local
      (reduced communicators, paper §3.3).
    """
    B, S, D = x.shape
    m = plan.model_axis
    msize = plan.model_size
    dp = plan.dp_axes
    E = params["router"].shape[-1]
    K = cfg.top_k
    if E % msize:
        from ..dist.shardings import ShardingError
        raise ShardingError(
            f"moe_block_ep: {E} (padded) experts not divisible by the "
            f"expert-parallel axis {m!r} (size {msize})")
    E_loc = E // msize

    def body(xl, router, we_g, we_1, we_2):
        # xl: (B_loc, S, D) model-replicated; we_*: (E_loc, D, F)
        Bl = xl.shape[0]
        T = Bl * S
        xt = xl.reshape(T, D)
        logits = (xt @ router).astype(jnp.float32)
        if E > cfg.n_experts:
            logits = jnp.where(jnp.arange(E)[None] >= cfg.n_experts, -1e30,
                               logits)
        probs = jax.nn.softmax(logits, -1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
            jnp.ones((T * K,), jnp.float32)) / (T * K)
        aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp)   # model-invarying already
        # ---- radix-bucket local tokens by expert (capacity-bounded) ----
        C = max(8, min(int(cfg.capacity_factor * T * K / E + 0.999), T))
        flat_e = gate_idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_s = flat_e[order]
        seg = jnp.searchsorted(e_s, jnp.arange(E + 1)).astype(jnp.int32)
        within = jnp.arange(T * K, dtype=jnp.int32) - \
            seg[jnp.clip(e_s, 0, E - 1)]
        keep = within < C
        slot = jnp.where(keep, e_s * C + within, E * C)
        tok_of = order // K
        xe = jnp.zeros((E * C, D), xl.dtype).at[slot].set(
            xt[tok_of], mode="drop").reshape(E, C, D)
        # ---- expert-parallel compute --------------------------------
        # Activations are model-replicated (Megatron TP), so every rank
        # already HAS all tokens: slice out the locally-owned experts,
        # compute, and psum partial outputs over the TP axis. Wire cost =
        # one (T, D) all-reduce — identical to a dense TP MLP; no
        # dispatch all-to-all is needed until activations become
        # sequence-sharded (seq_parallel), where the a2a variant applies.
        ridx = jax.lax.axis_index(m)
        x_loc = jax.lax.dynamic_slice_in_dim(xe, ridx * E_loc, E_loc, 0)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_loc, we_g)) * \
            jnp.einsum("ecd,edf->ecf", x_loc, we_1)
        y_loc = jnp.einsum("ecf,efd->ecd", h, we_2)    # (E_loc, C, D)
        # ---- combine (transpose SpMM with the gate values) ----------
        gates = jnp.zeros((E * C,), jnp.float32).at[slot].set(
            gate_vals.reshape(-1)[order], mode="drop")
        gates_full = gates.reshape(E, C)
        g_loc = jax.lax.dynamic_slice_in_dim(gates_full, ridx * E_loc,
                                             E_loc, 0)
        ye = (y_loc * g_loc[:, :, None].astype(y_loc.dtype)) \
            .reshape(E_loc * C, D)
        # local slots of my experts map back to token ids
        slot_full = jnp.where(keep, slot, E * C)
        my_lo = ridx * E_loc * C
        in_mine = (slot_full >= my_lo) & (slot_full < my_lo + E_loc * C)
        local_slot = jnp.where(in_mine, slot_full - my_lo, E_loc * C)
        contrib = ye[jnp.clip(local_slot, 0, E_loc * C - 1)]
        contrib = jnp.where(in_mine[:, None], contrib, 0)
        y_part = jnp.zeros((T, D), ye.dtype).at[tok_of].add(contrib)
        y = jax.lax.psum(y_part, m)
        return y.reshape(Bl, S, D), aux

    from jax.sharding import PartitionSpec as P
    dp_spec = dp if len(dp) > 1 else dp[0]
    from ..core.compat import shard_map as _shard_map
    y, aux = _shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(m, None, None), P(m, None, None), P(m, None, None)),
        out_specs=(P(dp_spec, None, None), P()),
    )(x, params["router"], params["we_g"], params["we_1"], params["we_2"])
    if cfg.n_shared_experts:
        xt = x.reshape(B * S, D)
        sh = _shared_experts(xt, params)
        y = y + sh.reshape(B, S, D).astype(y.dtype)
    return y.astype(x.dtype), aux


# --------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, arXiv:2405.21060)
# --------------------------------------------------------------------------

def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs; dt: (B, S, H) ≥0 step sizes; A: (H,) < 0 decay;
    Bm, Cm: (B, S, N) (single group). Returns (y, final_state[B,H,P,N]).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nchunk = S // chunk
    assert nchunk * chunk == S, (S, chunk)
    xc = xh.reshape(Bsz, nchunk, chunk, H, Pd)
    dtc = dt.reshape(Bsz, nchunk, chunk, H)
    Bc = Bm.reshape(Bsz, nchunk, chunk, N)
    Cc = Cm.reshape(Bsz, nchunk, chunk, N)
    dA = dtc * A[None, None, None, :]                 # (B, c, q, H) ≤ 0
    dA_cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum

    # ---- intra-chunk (quadratic, attention-like with decay kernel) ----
    # L[q1, q2] = exp(dA_cum[q1] - dA_cum[q2]) for q1 >= q2
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)    # (B, c, q, k)
    y_diag = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp",
                        scores, L, dtc, xc)

    # ---- chunk states:  states_c = Σ_k exp(dA_cum[last]-dA_cum[k])·dt·B·x
    decay_last = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)       # (B,c,q,H)
    states = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn",
                        Bc, decay_last, dtc, xc)              # (B,c,H,P,N)

    # ---- inter-chunk recurrence over chunk index -----------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                # (B,c,H)

    def step(carry, inp):
        st_prev = carry                                       # (B,H,P,N)
        st_c, dec_c = inp
        new = st_prev * dec_c[:, :, None, None] + st_c
        return new, st_prev

    # SSM states are kept in f32 (the standard precision choice for the
    # recurrence); products with bf16 inputs promote to f32 already
    init = jnp.zeros((Bsz, H, Pd, N), states.dtype) if init_state is None \
        else init_state.astype(states.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,c,H,P,N)

    # ---- off-diagonal contribution: y += C · exp(dA_cum) · prev_state --
    in_decay = jnp.exp(dA_cum)                                # (B,c,q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, in_decay, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, final


def ssd_decode_step(x1, dt1, A, B1, C1, state):
    """One-token SSD recurrence. x1: (B,1,H,P); B1/C1: (B,1,N)."""
    dA = jnp.exp(dt1[:, 0, :] * A[None, :])                   # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", B1[:, 0], dt1[:, 0], x1[:, 0])
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C1[:, 0], new_state)
    return y[:, None], new_state


def mamba_block(x, params, cfg: ModelConfig, *, cache=None):
    """Mamba2 block: in_proj → short conv → SSD → gated out_proj.

    cache (decode): dict(conv: (B, d_conv-1, Din+2N), state: (B,H,P,N)).
    """
    B, S, D = x.shape
    Din, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    # split projections (TP-shardable individually; DESIGN.md §5)
    z = x @ params["in_z"]                                    # (B,S,Din)
    xbc = x @ params["in_xbc"]                                # (B,S,Din+2N)
    dt = x @ params["in_dt"]                                  # (B,S,H)
    new_cache = None
    if cache is None:
        xbc_conv = _causal_conv(xbc, params["conv_w"], cfg.d_conv)
    else:
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)
        xbc_conv = _causal_conv(hist, params["conv_w"],
                                cfg.d_conv)[:, -S:]
        new_conv = hist[:, -(cfg.d_conv - 1):]
    xbc_conv = jax.nn.silu(xbc_conv)
    xin, Bm, Cm = jnp.split(xbc_conv, [Din, Din + N], axis=-1)
    xin = xin.reshape(B, S, H, Pd)
    dt = jax.nn.softplus(dt + params["dt_bias"])              # (B,S,H)
    A = -jnp.exp(params["A_log"])                             # (H,)
    if cache is None:
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk:
            pad = chunk - S % chunk
            y, _ = ssd_chunked(
                jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0))),
                jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), A,
                jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
                jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))), chunk)
            y = y[:, :S]
        else:
            y, _ = ssd_chunked(xin, dt, A, Bm, Cm, chunk)
    elif S == 1:
        y, new_state = ssd_decode_step(xin, dt, A, Bm, Cm, cache["state"])
        new_cache = dict(conv=new_conv, state=new_state)
    else:
        # prefill with cache: run the recurrence over S positions
        def one(state, inp):
            xt, dtt, Bt, Ct = inp
            yt, st = ssd_decode_step(xt[:, None], dtt[:, None], A,
                                     Bt[:, None], Ct[:, None], state)
            return st, yt[:, 0]

        st0 = cache["state"]
        new_state, ys = jax.lax.scan(
            one, st0, (xin.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                       Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2, 3)
        new_cache = dict(conv=new_conv, state=new_state)
    y = y + xin * params["D_skip"][None, None, :, None]
    y = y.reshape(B, S, Din)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], new_cache


def _causal_conv(x, w, width):
    """Depthwise causal conv. x: (B, S, C); w: (width, C)."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(width):
        out = out + pad[:, t:t + x.shape[1]] * w[t][None, None, :]
    return out
