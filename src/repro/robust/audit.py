"""Tiered invariant auditor for the distributed sparse containers.

Every operand and result in this stack is a capacity-padded COO tile family
with hard invariants (DESIGN.md §3/§4.3): live indices in tile bounds,
``SENTINEL`` padding exactly beyond ``nnz``, ``nnz ≤ cap``, and — for
``order='row'``/``'col'`` tagged objects — strictly increasing packed keys
per tile (sorted AND deduplicated). Silent corruption almost always breaks
one of these; this module checks them, at a level chosen per run:

  ``REPRO_AUDIT=off``       (default) zero checks, zero overhead — hooks are
                            one boolean read.
  ``REPRO_AUDIT=boundary``  structural invariants + packed-key/value
                            checksums bracketing every communication stage
                            (the SUMMA/3D/SpMSpV operand boundaries). Each
                            check costs one host transfer of the operand.
  ``REPRO_AUDIT=full``      boundary + sortedness/dedup/finiteness sweeps on
                            operands and results (the forensic setting).

A failed check raises :class:`AuditError` naming the site; the planner's
retry loop (core/plan.py) treats that as a failed attempt — re-running from
the pristine host-side inputs — and escalates to the degradation ladder
(robust/recover.py) when corruption persists.

Like :mod:`repro.robust.faults`, this module imports nothing from
``repro.core`` (core imports us); containers are duck-typed on their fields
and ``SENTINEL`` is the shared int32-max constant.
"""
from __future__ import annotations

import contextlib
import os
import zlib

import numpy as np

from ..obs import recorder as _obs
from .faults import SENTINEL

OFF, BOUNDARY, FULL = 0, 1, 2
_NAMES = {"off": OFF, "boundary": BOUNDARY, "full": FULL}

_env_level: int | None = None
_override: list[int] = []


class AuditError(RuntimeError):
    """An invariant or checksum check failed at a named site."""

    def __init__(self, msg: str, site: str = "?"):
        super().__init__(msg)
        self.site = site


def level() -> int:
    global _env_level
    if _override:
        return _override[-1]
    if _env_level is None:
        name = os.environ.get("REPRO_AUDIT", "off").strip().lower()
        if name not in _NAMES:
            raise ValueError(f"REPRO_AUDIT={name!r}: want off|boundary|full")
        _env_level = _NAMES[name]
    return _env_level


def enabled() -> bool:
    return level() > OFF


@contextlib.contextmanager
def at_level(name: str):
    """Scoped override: ``with audit.at_level('full'): ...`` (tests)."""
    _override.append(_NAMES[name] if isinstance(name, str) else int(name))
    try:
        yield
    finally:
        _override.pop()


# --------------------------------------------------------------------------
# container views (duck-typed — no repro.core import)
# --------------------------------------------------------------------------

def _views(obj):
    """(R, C|None, V, N, (bound_r, bound_c|None), order) host views.

    R/C are (ntile, cap) int, V (ntile, cap, ...), N (ntile,).
    """
    if hasattr(obj, "idx"):                      # DistSpVec
        I = np.asarray(obj.idx)
        cap = I.shape[-1]
        return (I.reshape(-1, cap), None,
                np.asarray(obj.val).reshape((-1, cap)
                                            + obj.val.shape[I.ndim:]),
                np.asarray(obj.nnz).reshape(-1), (obj.vb, None), "none")
    R = np.asarray(obj.row)
    cap = R.shape[-1]
    if hasattr(obj, "block_sizes"):              # DistSpMat3D
        tr, tc = obj.block_sizes()
    else:                                        # DistSpMat
        tr, tc = obj.mb, obj.nb
    return (R.reshape(-1, cap),
            np.asarray(obj.col).reshape(-1, cap),
            np.asarray(obj.val).reshape((-1, cap) + obj.val.shape[R.ndim:]),
            np.asarray(obj.nnz).reshape(-1), (tr, tc),
            getattr(obj, "order", "none"))


def _keys(R, C, bounds, order):
    """Packed int64 per-entry keys in the tile's order (padding -> max)."""
    tr, tc = bounds
    if C is None:
        k = R.astype(np.int64)
        pad = R == SENTINEL
    else:
        pad = (R == SENTINEL) | (C == SENTINEL)
        if order == "col":
            k = C.astype(np.int64) * (tr + 1) + R.astype(np.int64)
        else:
            k = R.astype(np.int64) * (tc + 1) + C.astype(np.int64)
    return np.where(pad, np.iinfo(np.int64).max, k)


# --------------------------------------------------------------------------
# invariant checks
# --------------------------------------------------------------------------

def _audit_views(R, C, V, N, bounds, order, where: str, lvl: int):
    cap = R.shape[-1]
    if (N < 0).any() or (N > cap).any():
        raise AuditError(f"{where}: nnz outside [0, cap={cap}] "
                         f"(min={N.min()}, max={N.max()})", where)
    live = np.arange(cap)[None, :] < N[:, None]
    tr, tc = bounds
    for name, A, bound in (("row", R, tr), ("col", C, tc)):
        if A is None:
            continue
        if (A[live] == SENTINEL).any():
            raise AuditError(f"{where}: SENTINEL {name} inside live region",
                             where)
        bad = A[live]
        if bad.size and (int(bad.min()) < 0 or int(bad.max()) >= bound):
            raise AuditError(
                f"{where}: {name} index out of bounds [0, {bound}) "
                f"(min={bad.min()}, max={bad.max()})", where)
        if (A[~live] != SENTINEL).any():
            raise AuditError(
                f"{where}: non-canonical padding ({name} != SENTINEL "
                "beyond nnz)", where)
    if lvl < FULL:
        return
    if np.issubdtype(V.dtype, np.floating):
        Vl = V.reshape(V.shape[0], cap, -1)
        lv = live[:, :, None] & np.ones(Vl.shape[-1], bool)
        if not np.isfinite(Vl[lv]).all():
            raise AuditError(f"{where}: non-finite value in live region",
                             where)
    keys = _keys(R, C, bounds, order)
    if order in ("row", "col"):
        d = np.diff(keys, axis=-1)
        both_live = live[:, 1:] & live[:, :-1]
        if (d[both_live] <= 0).any():
            raise AuditError(
                f"{where}: order='{order}' violated (keys not strictly "
                "increasing — unsorted or duplicate (row, col))", where)
    elif C is None:
        # vectors carry no order tag; still reject duplicate indices
        ks = np.sort(np.where(live, keys, np.iinfo(np.int64).max), axis=-1)
        dup = (np.diff(ks, axis=-1) == 0) & (ks[:, :-1]
                                             != np.iinfo(np.int64).max)
        if dup.any():
            raise AuditError(f"{where}: duplicate sparse-vector index "
                             "within a piece", where)


def audit_obj(obj, where: str, min_level: int = BOUNDARY):
    """Validate a distributed container's invariants at the current level."""
    lvl = level()
    if lvl < min_level:
        return
    _audit_views(*_views(obj), where, lvl)


# back-compat aliases for the three container families
audit_spmat = audit_obj
audit_spvec = audit_obj


# --------------------------------------------------------------------------
# checksums + communication bracketing
# --------------------------------------------------------------------------

def checksum_obj(obj) -> int:
    """CRC32 over (nnz, live packed keys, live values) — stored order."""
    R, C, V, N, bounds, order = _views(obj)
    cap = R.shape[-1]
    live = np.arange(cap)[None, :] < N[:, None]
    keys = _keys(R, C, bounds, order)
    crc = zlib.crc32(np.ascontiguousarray(N, np.int64).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(keys[live]).tobytes(), crc)
    Vl = V.reshape(V.shape[0], cap, -1)
    lv = live[:, :, None] & np.ones(Vl.shape[-1], bool)
    crc = zlib.crc32(np.ascontiguousarray(Vl[lv]).tobytes(), crc)
    return crc


def payload_nbytes(obj) -> int:
    """Live wire-payload bytes of a container: Σnnz × per-entry bytes.

    Index dtypes + the value payload (including trailing vdims) per live
    entry — the volume a real wire would move, and the quantity the
    ``comm.bytes.*`` / ``dist.compress.bytes_*`` obs counters accumulate.
    Deterministic (derives only from nnz and dtypes), cheap (one host
    transfer of the nnz array, nothing else).
    """
    n = int(np.sum(np.asarray(obj.nnz)))
    if hasattr(obj, "idx"):                      # DistSpVec
        base_ndim = obj.idx.ndim
        per = obj.idx.dtype.itemsize
    else:                                        # DistSpMat / DistSpMat3D
        base_ndim = obj.row.ndim
        per = obj.row.dtype.itemsize + obj.col.dtype.itemsize
    vper = obj.val.dtype.itemsize
    for d in obj.val.shape[base_ndim:]:
        vper *= d
    return n * (per + vper)


def guard_exchange(site: str, obj):
    """Bracket one simulated communication stage.

    checksum(pre) → apply any armed fault (the simulated in-flight
    corruption — jax arrays are immutable, so corrupting the operand at the
    boundary IS the wire model) → checksum(post); mismatch raises
    :class:`AuditError`. At audit level off the fault passes through
    undetected (the documented trade); with nothing armed, auditing off and
    the deadline guard disabled this is three boolean reads.

    The whole bracket additionally runs under the wall-time deadline of
    ``robust/deadline.ExchangeGuard`` (the topology tier): a hung or
    straggling exchange — provoked deterministically by a ``delay`` fault
    at ``dist.exchange_deadline``, which sleeps inside the timed region —
    raises :class:`~repro.robust.deadline.ExchangeTimeout` instead of
    blocking forever.
    """
    from . import deadline, faults
    f_on = faults.enabled()
    lvl = level()
    obs_on = _obs.recording()
    if not f_on and lvl < BOUNDARY and not deadline.enabled() \
            and not obs_on:
        return obj
    if obs_on:
        # the flight recorder's comm-volume tier: live payload bytes at
        # every guarded boundary, under a per-site span (DESIGN.md §9)
        _obs.counter_add("comm.bytes." + site, payload_nbytes(obj))
    with _obs.span(site):
        try:
            with deadline.watch(site):
                pre = checksum_obj(obj) if lvl >= BOUNDARY else None
                if f_on:
                    obj = faults.corrupt_obj(site, obj)
                if pre is not None:
                    post = checksum_obj(obj)
                    if post != pre:
                        raise AuditError(
                            f"{site}: packed-key/value checksum mismatch "
                            f"across exchange ({pre:#010x} -> {post:#010x})",
                            site)
        except AuditError as err:
            # deadline.watch already evented its own trips; only plain
            # checksum/invariant failures are counted here
            from .deadline import ExchangeTimeout
            if not isinstance(err, ExchangeTimeout):
                _obs.event("audit.failure", site=site, error=str(err))
                _obs.counter_add("audit.failures")
            raise
    return obj
