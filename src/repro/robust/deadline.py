"""Wall-time exchange deadlines, seeded retry backoff, topology errors.

The fault/audit subsystem (PR 5/6) recovers from *data* faults — corrupted
payloads caught by checksum brackets. This module adds the *topology* tier:
a hung collective or a persistently straggling peer produces no checksum
mismatch at all; today it would block the run forever. The
:class:`ExchangeGuard` wraps the same comm boundaries that
``audit.guard_exchange`` already brackets and gives each site a wall-time
deadline learned from a trailing-median model (generalizing
``launch/elastic.StepWatchdog`` from per-step to per-site):

  * **Warmup.** With fewer than ``min_samples`` recorded exchanges at a
    site, the budget is the flat ``startup_deadline`` (default 60 s) — a
    collective that hangs on the very first exchange still fails in bounded
    time instead of the 6-hour CI default.
  * **Steady state.** Budget = ``max(floor, grace × trailing median)``.
    The floor keeps a fast site (median in the microseconds) from tripping
    on an unrelated host hiccup.
  * **Escalation** is owned by the planner retry loops (core/plan.py):
    an :class:`ExchangeTimeout` (an ``AuditError`` subclass, so the
    existing retry machinery sees it) is retried from pristine inputs with
    deterministic seeded exponential backoff, then shed to the
    ``serial-schedule`` ladder rung, and only when the ladder is exhausted
    escalates to :class:`TopologyError` — the signal the elastic
    ``CheckpointedLoop`` turns into checkpoint → regrid → continue.

Determinism: backoff delays are drawn from ``numpy.random.default_rng``
keyed on (``REPRO_FAULT_SEED``, site, attempt) — the same chaos run
backs off identically. Stragglers are provoked on demand through the
``dist.exchange_deadline`` fault site (a ``delay`` fault armed there
sleeps inside the timed region of whichever guarded exchange runs next).

Like the rest of ``repro.robust``, this module imports nothing from
``repro.core`` (core imports us).
"""
from __future__ import annotations

import contextlib
import os
import time
import warnings
import zlib
from collections import deque

import numpy as np

from ..obs import recorder as _obs
from . import faults
from .audit import AuditError

# Fault site whose armed ``delay`` fires inside the timed region of the
# next guarded exchange — the deterministic stand-in for a hung collective.
DELAY_SITE = "dist.exchange_deadline"


class TopologyError(RuntimeError):
    """The process topology is no longer serviceable at a named site.

    Raised when the degradation ladder is exhausted under a persistent
    exchange deadline, or by an injected ``loop.device_loss`` fault. The
    elastic ``CheckpointedLoop`` responds by checkpointing and regridding
    onto a smaller process grid.
    """

    def __init__(self, msg: str, site: str = "?"):
        super().__init__(msg)
        self.site = site


class ExchangeTimeout(AuditError):
    """A guarded exchange exceeded its wall-time budget.

    Subclasses :class:`AuditError` so the planner retry loops treat a
    deadline trip exactly like a failed checksum — retry from pristine
    inputs — while ``isinstance`` checks can still tell the two apart
    (timeouts additionally back off and escalate to TopologyError).
    """

    def __init__(self, site: str, elapsed: float, budget: float):
        super().__init__(
            f"{site}: exchange exceeded wall-time deadline "
            f"({elapsed:.3f}s > budget {budget:.3f}s)", site)
        self.elapsed = elapsed
        self.budget_s = budget


class ExchangeGuard:
    """Per-site wall-time deadlines from a trailing-median model."""

    def __init__(self, *, grace: float = 4.0, window: int = 32,
                 min_samples: int = 5, floor: float = 1.0,
                 startup_deadline: float = 60.0,
                 backoff_base: float = 0.05, backoff_cap: float = 5.0,
                 max_retries: int = 3):
        self.grace = grace
        self.window = window
        self.min_samples = min_samples
        self.floor = floor
        self.startup_deadline = startup_deadline
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.max_retries = max_retries
        self._times: dict[str, deque] = {}
        self._trips: dict[str, int] = {}

    def budget(self, site: str) -> float:
        """Current wall-time budget for one exchange at ``site``."""
        ts = self._times.get(site)
        if ts is None or len(ts) < self.min_samples:
            return self.startup_deadline
        med = sorted(ts)[len(ts) // 2]
        return max(self.floor, med * self.grace)

    def record(self, site: str, dt: float):
        self._times.setdefault(site, deque(maxlen=self.window)).append(dt)

    def samples(self, site: str) -> int:
        return len(self._times.get(site, ()))

    def trips(self, site: str) -> int:
        """Deadline trips recorded at ``site`` (survives :meth:`reset`)."""
        return self._trips.get(site, 0)

    def sites(self) -> list[str]:
        """Every site with recorded samples or trips (sorted)."""
        return sorted(set(self._times) | set(self._trips))

    def stats(self, site: str) -> dict:
        """Public window state: ``{n, median_s, budget_s, trips}``.

        The supported way to inspect a site's timing model (obs.snapshot
        embeds this per site) — callers must not reach into ``_times``.
        ``median_s`` is None during warmup (fewer than one sample).
        """
        ts = self._times.get(site)
        n = len(ts) if ts else 0
        med = float(sorted(ts)[n // 2]) if n else None
        return {"n": n, "median_s": med,
                "budget_s": float(self.budget(site)),
                "trips": self.trips(site)}

    def reset(self, site: str | None = None):
        """Forget trailing times — for all sites or one.

        Called after a topology change or a schedule-ladder descent: the
        new configuration's exchanges have different timing, so budgets
        learned from the old one would either mask a regression or trip
        spuriously. Trip counts are diagnostics, not a timing model — they
        deliberately survive the reset.
        """
        if site is None:
            self._times.clear()
        else:
            self._times.pop(site, None)

    @contextlib.contextmanager
    def watch(self, site: str):
        """Time one exchange at ``site``; raise ExchangeTimeout over budget.

        The ``dist.exchange_deadline`` delay fault fires *inside* the timed
        region, so an armed straggler is seen exactly as a slow wire would
        be. Tripped times are NOT recorded — a straggler must not poison
        the trailing median it is judged against.
        """
        t0 = time.monotonic()
        faults.maybe_delay(DELAY_SITE)
        yield
        dt = time.monotonic() - t0
        b = self.budget(site)
        if dt > b:
            self._trips[site] = self._trips.get(site, 0) + 1
            _obs.event("deadline.trip", site=site, elapsed_s=dt, budget_s=b)
            _obs.counter_add("deadline.trips")
            raise ExchangeTimeout(site, dt, b)
        self.record(site, dt)

    def backoff_delay(self, site: str, attempt: int) -> float:
        """Deterministic seeded exponential backoff before retry ``attempt``.

        ``min(cap, base·2^(attempt-1))`` jittered to 50–150 % by an rng
        keyed on (global fault seed, site, attempt) — reproducible under a
        pinned ``REPRO_FAULT_SEED``, decorrelated across sites.
        """
        rng = np.random.default_rng(
            faults.global_seed() ^ zlib.crc32(site.encode())
            ^ (int(attempt) << 20))
        base = min(self.backoff_cap, self.backoff_base * 2 ** (attempt - 1))
        return base * (0.5 + float(rng.random()))


# --------------------------------------------------------------------------
# module-level default guard (what audit.guard_exchange and plan.py use)
# --------------------------------------------------------------------------

_GUARD: ExchangeGuard | None = None
_env_checked = False


def _default_guard() -> ExchangeGuard | None:
    """Build the guard from the environment on first use.

    ``REPRO_DEADLINE=off`` disables deadline enforcement entirely (the
    delay fault site still fires); a float value overrides
    ``startup_deadline``; unset/``auto`` uses the defaults.
    """
    global _GUARD, _env_checked
    if not _env_checked:
        _env_checked = True
        spec = os.environ.get("REPRO_DEADLINE", "auto").strip().lower()
        if spec == "off":
            _GUARD = None
        elif spec in ("", "auto"):
            _GUARD = ExchangeGuard()
        else:
            _GUARD = ExchangeGuard(startup_deadline=float(spec))
    return _GUARD


def active_guard() -> ExchangeGuard | None:
    return _default_guard()


def enabled() -> bool:
    return _default_guard() is not None


@contextlib.contextmanager
def configure(**kw):
    """Scoped override guard: ``with deadline.configure(floor=0.05): ...``.

    Installs a fresh :class:`ExchangeGuard` built with ``kw`` for the scope
    (tests, chaos runs); the previous guard — and its learned budgets — is
    restored on exit. ``configure(off=True)`` disables enforcement.
    """
    global _GUARD, _env_checked
    _default_guard()
    prev = _GUARD
    _GUARD = None if kw.pop("off", False) else ExchangeGuard(**kw)
    try:
        yield _GUARD
    finally:
        _GUARD = prev


@contextlib.contextmanager
def watch(site: str):
    """Module-level watch using the active guard (no-op timing when off)."""
    g = _default_guard()
    if g is None:
        # enforcement off: still fire any armed straggler fault so chaos
        # specs behave identically with and without the guard
        faults.maybe_delay(DELAY_SITE)
        yield
        return
    with g.watch(site):
        yield


def reset(site: str | None = None):
    g = _default_guard()
    if g is not None:
        g.reset(site)


def stats(site: str) -> dict:
    """Module-level :meth:`ExchangeGuard.stats` on the active guard.

    ``{n: 0, median_s: None, budget_s: None, trips: 0}`` when deadline
    enforcement is off — callers never touch guard internals.
    """
    g = _default_guard()
    if g is None:
        return {"n": 0, "median_s": None, "budget_s": None, "trips": 0}
    return g.stats(site)


def sites() -> list[str]:
    """Sites the active guard has state for (empty when off)."""
    g = _default_guard()
    return g.sites() if g is not None else []


def backoff_sleep(site: str, attempt: int):
    """Warn + sleep the deterministic backoff before retry ``attempt``."""
    g = _default_guard()
    if g is None:
        return
    d = g.backoff_delay(site, attempt)
    _obs.event("deadline.backoff", site=site, attempt=attempt, delay_s=d)
    _obs.counter_add("deadline.backoffs")
    warnings.warn(
        f"robust: exchange deadline at {site} — backing off {d * 1e3:.1f}ms "
        f"before retry {attempt}", RuntimeWarning, stacklevel=3)
    time.sleep(d)


def maybe_device_loss(site: str = "loop.device_loss"):
    """Raise :class:`TopologyError` when a fault fires at ``site``.

    Any fault kind armed at the site triggers the loss — ``crash`` is the
    conventional spec (``loop.device_loss:crash:at=4``). This models the
    runtime noticing a peer is gone at an iteration boundary.
    """
    f = faults.fire(site)
    if f is not None:
        raise TopologyError(
            f"injected device loss at {site} (hit {f.hits})", site)
