"""Robustness subsystem: fault injection, invariant audit, degradation.

- :mod:`repro.robust.faults`  — deterministic seeded fault injection
  (``REPRO_FAULTS`` env / :func:`faults.inject`) at named sites.
- :mod:`repro.robust.audit`   — tiered invariant auditor (``REPRO_AUDIT``)
  + checksum bracketing of communication stages.
- :mod:`repro.robust.recover` — degradation ladder and
  :class:`~repro.robust.recover.CheckpointedLoop`.

``faults``/``audit`` are import-light (stdlib + numpy) so ``repro.core``
modules can hook them at module scope; ``recover`` lazy-imports core.
"""
from . import audit, faults, recover
from .audit import AuditError
from .faults import InjectedCrash
from .recover import LADDER, CheckpointedLoop

__all__ = ["audit", "faults", "recover", "AuditError", "InjectedCrash",
           "LADDER", "CheckpointedLoop"]
