"""Robustness subsystem: fault injection, invariant audit, degradation.

- :mod:`repro.robust.faults`  — deterministic seeded fault injection
  (``REPRO_FAULTS`` env / :func:`faults.inject`) at named sites.
- :mod:`repro.robust.audit`   — tiered invariant auditor (``REPRO_AUDIT``)
  + checksum bracketing of communication stages.
- :mod:`repro.robust.deadline` — wall-time exchange deadlines
  (``REPRO_DEADLINE``), seeded retry backoff, and topology errors — the
  tier that catches hung collectives and dead devices.
- :mod:`repro.robust.recover` — degradation ladder and the elastic
  :class:`~repro.robust.recover.CheckpointedLoop`.

``faults``/``audit``/``deadline`` are import-light (stdlib + numpy) so
``repro.core`` modules can hook them at module scope; ``recover``
lazy-imports core.
"""
from . import audit, deadline, faults, recover
from .audit import AuditError
from .deadline import ExchangeGuard, ExchangeTimeout, TopologyError
from .faults import InjectedCrash
from .recover import LADDER, CheckpointedLoop

__all__ = ["audit", "deadline", "faults", "recover", "AuditError",
           "ExchangeGuard", "ExchangeTimeout", "TopologyError",
           "InjectedCrash", "LADDER", "CheckpointedLoop"]
