"""Deterministic fault injection for the distributed sparse stack.

Long-running combinatorial workloads (HipMCL over days, standing PageRank
answers) see silent data corruption, partial failures and stragglers as
routine events — and a fault story is only credible if every failure mode
can be *provoked on demand, deterministically*. This module is the provoker:
a registry of named **fault sites** threaded through the stack's host-level
boundaries (the points where tiles would cross the network, where plans read
overflow flags, where checkpoints and matrix files hit disk), each of which
consults the registry and — when an armed fault's activation window matches
— perturbs the data flowing through it.

Design rules:

  * **Deterministic.** Every fault carries a seed; corruption draws from
    ``numpy.random.default_rng`` keyed on (fault seed, global seed, site
    name). The same ``REPRO_FAULTS``/``REPRO_FAULT_SEED`` produce the same
    corruption bit-for-bit — CI pins them (the chaos-smoke job).
  * **Zero overhead when disarmed.** ``enabled()`` is one module-global
    boolean read; every hook checks it first.
  * **Host-boundary semantics.** jax arrays are immutable and collectives
    run inside traced programs, so "corruption in flight" is modeled by
    corrupting the operand at the host-level call boundary *before* the
    traced collective consumes it — observationally identical to the wire
    flipping bits. Sites with ``at=N`` count *activations* (host calls).
    The one trace-time site (``merge.kv_ok``) instead fires on every traced
    call while armed — documented on :func:`trace_fault`.
  * **No repro imports at module scope.** Core modules import this module;
    anything from ``repro.core`` is imported lazily inside functions.

Spec grammar (env ``REPRO_FAULTS`` or :func:`inject`)::

    site:kind[:key=val[,key=val...]][;site2:kind2...]

    e.g.  REPRO_FAULTS="spgemm2d.comm_a:nan:at=2,seed=7;loop.crash:crash"

Kinds: ``nan`` ``corrupt_val`` ``corrupt_idx`` ``drop`` ``dup`` ``flip``
``truncate`` ``corrupt_bytes`` ``crash`` ``delay``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
import zlib

import numpy as np

# Matches core.coo.SENTINEL (int32 max) — duplicated here so this module
# stays importable before repro.core exists (core modules import us).
SENTINEL = 2**31 - 1

# Every named fault site in the stack, with the boundary it models.
# tests/test_faults.py asserts its chaos matrix covers ALL of these.
KNOWN_SITES = {
    "dist.assemble": "host COO -> DistSpMat tile assembly",
    "spgemm2d.comm_a": "2D SUMMA: A entering the rotation/allgather",
    "spgemm2d.comm_b": "2D SUMMA: B entering the rotation/allgather",
    "spgemm3d.comm_a": "3D CA SpGEMM: A entering the per-layer multiply",
    "spgemm3d.comm_b": "3D CA SpGEMM: B entering the per-layer multiply",
    "spmspv.comm_x": "SpMSpV: frontier x entering the 'row' all-gather",
    "dist.compressed_exchange": "2D SUMMA: int8-compressed value payload "
                                "entering the exchange collectives",
    "merge.kv_ok": "merge engine: kv-tree overflow flag (trace-time)",
    "plan.spgemm.ok": "planner: SpGEMM ok flags read on the host",
    "plan.spmspv.ok": "planner: SpMSpV ok flags read on the host",
    "checkpoint.leaf": "checkpoint leaf file bytes on disk",
    "io.mm_body": "MatrixMarket body byte stream during read",
    "io.bin_body": "binary-format body byte stream after write",
    "loop.crash": "iterative app: hard crash at iteration start",
    "loop.delay": "iterative app: straggler delay inside an iteration",
    "dist.exchange_deadline": "hung/straggling collective: delay inside the "
                              "timed region of a guarded exchange "
                              "(robust/deadline.ExchangeGuard)",
    "loop.device_loss": "iterative app: device/node loss at iteration start "
                        "(TopologyError -> checkpoint, regrid, continue)",
}


class InjectedCrash(RuntimeError):
    """Raised by a ``crash`` fault — models a process dying mid-run."""


@dataclasses.dataclass
class Fault:
    site: str
    kind: str
    at: int = 1          # fire on the at-th .. (at+count-1)-th activation
    count: int = 1
    seed: int = 0
    amount: float = 0.25  # fraction of entries / seconds of delay
    hits: int = 0        # activations seen (mutable bookkeeping)
    fired: int = 0       # activations that actually fired


_FAULTS: list[Fault] = []
_ENABLED = False         # fast-path flag, kept in sync with _FAULTS
_ENV_LOADED = False


def _parse_spec(spec: str) -> list[Fault]:
    out = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        if len(bits) < 2:
            raise ValueError(f"bad fault spec {part!r} (want site:kind[:k=v])")
        site, kind = bits[0], bits[1]
        kw = {}
        if len(bits) > 2:
            for kv in bits[2].split(","):
                k, _, v = kv.partition("=")
                kw[k] = float(v) if k == "amount" else int(v)
        out.append(Fault(site, kind, **kw))
    return out


def _ensure_env():
    global _ENV_LOADED, _ENABLED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    spec = os.environ.get("REPRO_FAULTS", "")
    if spec:
        _FAULTS.extend(_parse_spec(spec))
        _ENABLED = bool(_FAULTS)


def global_seed() -> int:
    return int(os.environ.get("REPRO_FAULT_SEED", "0"))


def enabled() -> bool:
    """One-boolean fast path; hooks bail here when nothing is armed."""
    if not _ENV_LOADED:
        _ensure_env()
    return _ENABLED


def active() -> list[Fault]:
    _ensure_env()
    return list(_FAULTS)


def reset_counters():
    for f in _FAULTS:
        f.hits = f.fired = 0


@contextlib.contextmanager
def inject(*specs: str):
    """Arm faults for a scope: ``with inject("spgemm2d.comm_a:nan"): ...``.

    Counters of the injected faults start at zero and the previous registry
    is restored (with its counters) on exit.
    """
    global _ENABLED
    _ensure_env()
    added = []
    for s in specs:
        added.extend(_parse_spec(s))
    _FAULTS.extend(added)
    _ENABLED = bool(_FAULTS)
    try:
        yield added
    finally:
        for f in added:
            _FAULTS.remove(f)
        _ENABLED = bool(_FAULTS)


def fire(site: str) -> Fault | None:
    """Count one activation of ``site``; return the fault if it fires now."""
    if not enabled():
        return None
    for f in _FAULTS:
        if f.site == site:
            f.hits += 1
            if f.at <= f.hits < f.at + f.count:
                f.fired += 1
                return f
    return None


def trace_fault(site: str) -> Fault | None:
    """Armed-fault lookup WITHOUT activation counting.

    For sites inside traced (jit/shard_map) code: tracing happens once per
    compilation, not once per execution, so counting activations there would
    be meaningless. A trace-time fault applies to *every* call while armed —
    use :func:`inject` scopes (or count-free env specs) to bound it.
    """
    if not enabled():
        return None
    for f in _FAULTS:
        if f.site == site:
            f.fired += 1
            return f
    return None


def _rng(f: Fault) -> np.random.Generator:
    return np.random.default_rng(
        (int(f.seed) << 16) ^ global_seed() ^ zlib.crc32(f.site.encode()))


# --------------------------------------------------------------------------
# corruption helpers (host-level, numpy in / jax out)
# --------------------------------------------------------------------------

def _corrupt_tiles(f: Fault, row, col, val, nnz, has_col: bool):
    """Apply ``f`` to one tile of a capacity-padded tile family.

    Arrays are (..., cap) numpy copies; returns them mutated. ``row`` (and
    ``col`` when present) use SENTINEL padding; ``nnz`` counts live slots.
    """
    cap = row.shape[-1]
    R = row.reshape(-1, cap)
    C = col.reshape(-1, cap) if has_col else None
    V = val.reshape((-1, cap) + val.shape[row.ndim:])
    N = nnz.reshape(-1)
    rng = _rng(f)
    livable = np.nonzero(N > 0)[0]
    if livable.size == 0:
        return row, col, val, nnz
    t = int(rng.choice(livable))
    n = int(N[t])
    k = max(1, min(n, int(round(f.amount * n))))
    idxs = rng.choice(n, size=k, replace=False)
    if f.kind == "nan":
        if np.issubdtype(V.dtype, np.floating):
            V[t, idxs] = np.nan
        else:
            V[t, idxs] = np.iinfo(V.dtype).max
    elif f.kind == "corrupt_val":
        if np.issubdtype(V.dtype, np.integer):
            # narrow wire dtypes (int8 compressed payloads): numpy 2
            # rejects out-of-range Python scalars — widen, then truncate
            # back with C-cast wraparound
            V[t, idxs] = (V[t, idxs].astype(np.int64) * 1000 + 7) \
                .astype(V.dtype)
        else:
            V[t, idxs] = V[t, idxs] * 1000 + 7
    elif f.kind == "corrupt_idx":
        # out of tile bounds but not the padding sentinel
        R[t, idxs] = 2**30 + np.arange(k, dtype=R.dtype)
    elif f.kind == "drop":
        # silently lose k entries: compact the live prefix and shrink nnz —
        # only a checksum (or a result oracle) can see this one
        keep = np.ones(cap, bool)
        keep[idxs] = False
        keep[n:] = False
        m = int(keep.sum())
        for A, pad in ((R, SENTINEL), (C, SENTINEL), (V, 0)):
            if A is None:
                continue
            live = A[t][keep]
            A[t][:m] = live
            A[t][m:] = pad
        N[t] = m
    elif f.kind == "dup":
        if n < cap:   # need slack to duplicate into; else corrupt instead
            src = int(idxs[0])
            R[t, n] = R[t, src]
            if C is not None:
                C[t, n] = C[t, src]
            V[t, n] = V[t, src]
            N[t] = n + 1
        else:
            V[t, idxs] = V[t, idxs] * 1000 + 7
    else:
        raise ValueError(f"fault kind {f.kind!r} cannot corrupt tiles")
    return row, col, val, nnz


def corrupt_spmat(site: str, m):
    """Fault hook for DistSpMat / DistSpMat3D operands at a comm boundary."""
    f = fire(site)
    if f is None:
        return m
    import jax.numpy as jnp
    row = np.array(m.row)
    col = np.array(m.col)
    val = np.array(m.val)
    nnz = np.array(m.nnz)
    row, col, val, nnz = _corrupt_tiles(f, row, col, val, nnz, has_col=True)
    return dataclasses.replace(m, row=jnp.asarray(row), col=jnp.asarray(col),
                               val=jnp.asarray(val), nnz=jnp.asarray(nnz))


def corrupt_spvec(site: str, v):
    """Fault hook for DistSpVec operands at a comm boundary."""
    f = fire(site)
    if f is None:
        return v
    import jax.numpy as jnp
    idx = np.array(v.idx)
    val = np.array(v.val)
    nnz = np.array(v.nnz)
    idx, _, val, nnz = _corrupt_tiles(f, idx, None, val, nnz, has_col=False)
    return dataclasses.replace(v, idx=jnp.asarray(idx), val=jnp.asarray(val),
                               nnz=jnp.asarray(nnz))


def corrupt_obj(site: str, obj):
    """Dispatch on the distributed container's fields (duck-typed)."""
    return corrupt_spvec(site, obj) if hasattr(obj, "idx") \
        else corrupt_spmat(site, obj)


def flip_ok(site: str, ok):
    """Flip a planner overflow flag to all-False (models a lying kernel)."""
    f = fire(site)
    if f is None:
        return ok
    import jax.numpy as jnp
    return jnp.zeros_like(jnp.asarray(ok))


def corrupt_bytes(site: str, data: bytes) -> bytes:
    """Fault hook for an in-memory byte stream (I/O read paths)."""
    f = fire(site)
    if f is None or not data:
        return data
    rng = _rng(f)
    if f.kind == "truncate":
        keep = max(1, int(len(data) * (1.0 - f.amount)))
        return data[:keep]
    buf = bytearray(data)
    k = max(1, int(len(buf) * min(f.amount, 1.0) * 0.05))
    for pos in rng.integers(0, len(buf), size=k):
        buf[pos] = int(rng.integers(0, 256))
    return bytes(buf)


def corrupt_file(site: str, path: str):
    """Fault hook for a file just written to disk (checkpoint leaves)."""
    f = fire(site)
    if f is None:
        return
    size = os.path.getsize(path)
    if f.kind == "truncate":
        with open(path, "r+b") as fh:
            fh.truncate(max(1, int(size * (1.0 - f.amount))))
        return
    rng = _rng(f)
    with open(path, "r+b") as fh:
        # flip bytes in the back half: past any .npy header, into the data
        for pos in rng.integers(size // 2, size, size=max(4, size // 256)):
            fh.seek(int(pos))
            b = fh.read(1)
            fh.seek(int(pos))
            fh.write(bytes([b[0] ^ 0xFF]))


def maybe_crash(site: str):
    """Raise InjectedCrash when a ``crash`` fault fires at ``site``."""
    f = fire(site)
    if f is not None:
        raise InjectedCrash(f"injected crash at {site} (hit {f.hits})")


def maybe_delay(site: str):
    """Sleep ``amount`` seconds when a ``delay`` fault fires (straggler)."""
    f = fire(site)
    if f is not None:
        time.sleep(f.amount)
