"""Graceful degradation + checkpointed iteration for long-running workloads.

Two recovery mechanisms, both loud (one warning per decision, the decision
recorded where the caller can see it):

**The degradation ladder.** When a planned multiply keeps failing — audit
checksum mismatches that survive plain retries, or overflow flags still
false at the worst-case capacity ceiling (the "ok flags disagree with the
symbolic bound" state that previously just raised) — the planner walks a
documented ladder of progressively more conservative pipeline configurations
instead of dying (DESIGN.md §8):

    1. ``serial-schedule``      overlapped / hybrid / compressed exchange
                                schedule -> bulk-synchronous Cannon rotation
                                (overlap off, compression off; §4.8). The
                                recorded entry names WHICH schedule features
                                were abandoned: ``serial-schedule:overlap+
                                schedule=hybrid+compress=int8``.
    2. ``postfilter``           fused masked multiply  -> unmasked multiply
                                + explicit post-filter (mask semantics kept,
                                pushdown win given up)
    3. ``sort-merge``           deferred/incremental merge engine -> the
                                seed concat-and-sort merge
    4. ``legacy-dedup``         packed-key dedup -> the seed two-key sort
                                (process-global: ``merge.force_legacy_dedup``)
    5. ``pure-jax-segreduce``   accelerator segmented-reduce kernel -> the
                                pure-JAX paths (process-global uninstall)

Each rung taken is appended to the plan's ``degraded`` tuple (rungs that
abandon a configuration record it after a ``:``, so degraded runs are
diagnosable from the plan object alone). Rungs 4/5 flip process-global
switches — once a kernel is implicated, every later call avoids it until
:func:`reset_degradation`.

**CheckpointedLoop.** Iterative apps (PageRank / HipMCL / FastSV) wrap their
iteration in this class to get per-iteration checkpoint/resume in the
``train/checkpoint.py`` atomic-dir format: state is a flat ``{name: array}``
dict, saved after each iteration, restored (CRC-verified, falling back past
corrupted steps) on restart. Because each app's loop body is a pure function
of its state dict, a crashed-and-resumed run replays the remaining
iterations bitwise-identically to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from ..obs import recorder as _obs
from . import faults
from .deadline import TopologyError, maybe_device_loss

LADDER = ("serial-schedule", "postfilter", "sort-merge", "legacy-dedup",
          "pure-jax-segreduce")

# Rungs meaningful per planned-op family (SpMSpV has no merge-engine path
# and no overlapped/compressed exchange schedule).
_RUNGS = {"spgemm": LADDER,
          "spmspv": ("postfilter", "pure-jax-segreduce")}


def _fancy_schedule(plan) -> list:
    """Schedule features the 'serial-schedule' rung would abandon."""
    desc = []
    if getattr(plan, "overlap", False):
        desc.append("overlap")
    s = getattr(plan, "schedule", None)
    if s not in (None, "rotate"):
        desc.append("schedule=" + (s if isinstance(s, str) else "hybrid"))
    if getattr(plan, "compress", None) is not None:
        desc.append(f"compress={plan.compress}")
    return desc


def next_rung(plan, mask, kind: str = "spgemm") -> str | None:
    """First untried, applicable ladder rung for ``plan`` (None = exhausted)."""
    # rungs that abandon a configuration record it as 'rung:<what>' — match
    # on the rung name so a taken rung is never offered twice
    taken = {t.split(":", 1)[0] for t in getattr(plan, "degraded", ())}
    for rung in _RUNGS[kind]:
        if rung in taken:
            continue
        if rung == "serial-schedule":
            if _fancy_schedule(plan):
                return rung
        elif rung == "postfilter":
            if mask is not None:
                return rung
        elif rung == "sort-merge":
            if getattr(plan, "merge", "sort") != "sort":
                return rung
        elif rung == "legacy-dedup":
            from ..core import merge
            if not merge.legacy_dedup_forced():
                return rung
        elif rung == "pure-jax-segreduce":
            from ..core import semiring
            if semiring._SEGREDUCE_BACKEND is not None \
                    or not semiring._SEGREDUCE_RESOLVED:
                return rung
    return None


def apply_rung(rung: str, plan):
    """Take ``rung``: warn once, flip switches, record it on the plan.

    The ``postfilter`` rung only records the decision — the caller owns the
    mask and must drop it (and post-apply it) itself, re-planning capacities
    for the unmasked output.
    """
    warnings.warn(
        f"robust: degrading pipeline -> {rung!r} "
        f"(after {getattr(plan, 'attempts', '?')} attempts; "
        f"ladder so far: {getattr(plan, 'degraded', ())})",
        RuntimeWarning, stacklevel=3)
    # mirror the warning into the flight recorder so a REPRO_TRACE capture
    # is self-contained — shed detail must not live only on stderr
    _obs.event("ladder.rung", rung=rung,
               attempts=getattr(plan, "attempts", None),
               prior=",".join(getattr(plan, "degraded", ())))
    _obs.counter_add("ladder.rungs")
    kw = dict(degraded=tuple(getattr(plan, "degraded", ())) + (rung,))
    if rung == "serial-schedule":
        # record WHICH schedule configuration was abandoned (bugfix: merge
        # rungs always recorded themselves; schedule descent now does too)
        what = "+".join(_fancy_schedule(plan)) or "none"
        kw["degraded"] = tuple(getattr(plan, "degraded", ())) \
            + (f"{rung}:{what}",)
        kw.update(overlap=False, schedule="rotate", compress=None,
                  variant="rotation")
    elif rung == "sort-merge" and hasattr(plan, "merge"):
        kw["merge"] = "sort"
    elif rung == "legacy-dedup":
        from ..core import merge
        merge.force_legacy_dedup(True)
        if hasattr(plan, "merge"):
            kw["merge"] = "sort"    # the legacy dedup lives on the sort path
    elif rung == "pure-jax-segreduce":
        from ..core import semiring
        semiring.register_segment_reduce_backend(None)
    return dataclasses.replace(plan, **{k: v for k, v in kw.items()
                                        if hasattr(plan, k)})


def reset_degradation():
    """Undo the process-global rungs (tests; a fresh job starts clean)."""
    from ..core import merge, semiring
    merge.force_legacy_dedup(False)
    semiring._SEGREDUCE_BACKEND = None
    semiring._SEGREDUCE_RESOLVED = False


# --------------------------------------------------------------------------
# explicit post-filters (the semantics the `postfilter` rung falls back to)
# --------------------------------------------------------------------------

def postfilter_2d(c, mask, sr, *, mesh):
    """Apply MaskSpec semantics to an already-computed unmasked C."""
    from ..core.mask import apply_val_pred, filter_tile, local_mask
    from ..core.matops import mat_apply_local, mat_ewise_local
    if mask.mat is not None:
        def fn(tc, tm):
            lm = local_mask(tm, pred=mask.pred, complement=mask.complement)
            return filter_tile(tc, lm, sr.add.identity)
        c = mat_ewise_local(c, mask.mat, fn, mesh=mesh)
    if mask.val_pred is not None:
        c = mat_apply_local(
            c, lambda t: apply_val_pred(t, mask.val_pred, sr.add.identity),
            mesh=mesh)
    return c


def postfilter_spvec(y, mask):
    """Apply a vector MaskSpec to an already-computed unmasked SpMSpV y."""
    import jax.numpy as jnp
    from ..core.matops import spvec_mask
    pred = mask.pred
    if mask.complement:
        return spvec_mask(y, mask.vec,
                          lambda xv, vv: ~jnp.asarray(pred(vv)))
    return spvec_mask(y, mask.vec, lambda xv, vv: jnp.asarray(pred(vv)))


# --------------------------------------------------------------------------
# checkpointed iteration
# --------------------------------------------------------------------------

_DONE_KEY = "__loop_done__"


class CheckpointedLoop:
    """Per-iteration checkpoint/resume for iterative graph apps.

    ``state`` is a FLAT dict of arrays (so restore needs no shape template —
    iterates like HipMCL's change capacity between iterations) and ``body``
    is ``body(it, state) -> (state, done)``, pure given ``state``. With
    ``ckpt_dir=None`` the loop runs bare (identical iteration sequence, no
    I/O) — the bitwise-resume contract is exactly that a crashed run,
    restarted with the same ``ckpt_dir``, finishes with the same state as
    the bare run.

    Fault sites: ``loop.crash`` (InjectedCrash at iteration start, before
    any state mutation), ``loop.delay`` (straggler sleep; flagged through
    the optional ``launch.elastic.StepWatchdog``) and ``loop.device_loss``
    (TopologyError at iteration start — the elastic path below).

    **Elastic topology recovery.** A :class:`TopologyError` — injected
    device loss, or a planned multiply whose degradation ladder was
    exhausted under a persistent exchange deadline — is caught at the
    iteration boundary: the last completed state is checkpointed, then

      * with an ``on_topology(state, err) -> state`` hook, the hook regrids
        (rebuild the mesh, ``DistSpMat.regrid`` onto the smaller grid,
        re-derive grid-shaped scratch) and the SAME iteration re-runs on
        the new topology — the watchdog is reset so old-grid step times
        don't poison the new budget;
      * without a hook the error propagates — a supervisor restarts the
        process under a smaller ``REPRO_DEVICES`` and ``resume()`` picks up
        from the checkpoint (state dicts are mesh-independent global
        arrays, so restoring onto any grid just works).

    Persistent stragglers get the same treatment one tier down: after
    ``straggler_patience`` consecutive over-budget iterations, the optional
    ``on_straggler(it, elapsed)`` hook fires (re-plan the hybrid exchange
    schedule away from the slow stage — ``core/plan.demote_stage``) and the
    watchdog is reset to learn the re-planned timing.
    """

    def __init__(self, ckpt_dir: str | None = None, *, every: int = 1,
                 keep: int = 3, watchdog=None, on_topology=None,
                 max_topology_events: int = 2, on_straggler=None,
                 straggler_patience: int = 3, name: str = "loop"):
        # ``name`` labels this loop's obs span site (``<name>.iter``) so
        # per-app iteration timings separate in trace_summary
        self.name = name
        self.ckpt_dir = ckpt_dir
        self.every = max(int(every), 1)
        self.keep = keep
        self.watchdog = watchdog
        self.on_topology = on_topology
        self.max_topology_events = max_topology_events
        self.on_straggler = on_straggler
        self.straggler_patience = max(int(straggler_patience), 1)

    def resume(self, state: dict):
        """(start_iteration, state): restored when a checkpoint exists."""
        if not self.ckpt_dir:
            return 0, state
        from ..train.checkpoint import restore_flat
        try:
            restored, step = restore_flat(self.ckpt_dir)
        except FileNotFoundError:
            return 0, state
        done = bool(np.asarray(restored.pop(_DONE_KEY, False)))
        return (-1 if done else step + 1), restored

    def _save(self, it: int, state: dict, done: bool):
        from ..train.checkpoint import save_checkpoint
        tree = dict(state)
        tree[_DONE_KEY] = np.asarray(done)
        save_checkpoint(self.ckpt_dir, it, tree, keep=self.keep)

    def run(self, state: dict, body, max_iters: int) -> dict:
        start, state = self.resume(state)
        if start < 0:                       # checkpointed run already done
            return state
        wd = self.watchdog
        topo_events = 0
        straggles = 0
        it = start
        while it < max_iters:
            faults.maybe_crash("loop.crash")
            try:
                maybe_device_loss("loop.device_loss")
                if wd is not None:
                    wd.start()
                faults.maybe_delay("loop.delay")
                with _obs.span(self.name + ".iter", it=it):
                    state, done = body(it, state)
            except TopologyError as err:
                # `state` is the last COMPLETED iteration's output — save
                # it (step it-1) so a restarted process resumes by redoing
                # exactly the interrupted iteration, never skipping it
                if self.ckpt_dir and it > 0:
                    self._save(it - 1, state, False)
                topo_events += 1
                if self.on_topology is None \
                        or topo_events > self.max_topology_events:
                    raise
                warnings.warn(
                    f"robust: topology fault at iteration {it} ({err}) — "
                    f"checkpointed, regridding via on_topology "
                    f"({topo_events}/{self.max_topology_events})",
                    RuntimeWarning, stacklevel=2)
                _obs.event("loop.topology", loop=self.name, it=it,
                           error=str(err), n=topo_events)
                _obs.counter_add("loop.topology_events")
                state = self.on_topology(state, err)
                if wd is not None:
                    wd.reset()              # old-grid step times are stale
                continue                    # re-run the SAME iteration
            if wd is not None:
                dt = wd.stop()
                if wd.is_straggling(dt):
                    warnings.warn(
                        f"robust: iteration {it} straggling "
                        f"({dt:.3f}s > budget {wd.budget():.3f}s)",
                        RuntimeWarning, stacklevel=2)
                    _obs.event("loop.straggler", loop=self.name, it=it,
                               elapsed_s=dt, budget_s=wd.budget())
                    _obs.counter_add("loop.stragglers")
                    straggles += 1
                    if self.on_straggler is not None \
                            and straggles >= self.straggler_patience:
                        warnings.warn(
                            f"robust: {straggles} consecutive straggling "
                            "iterations — invoking on_straggler to re-plan "
                            "around the slow stage", RuntimeWarning,
                            stacklevel=2)
                        self.on_straggler(it, dt)
                        wd.reset()          # learn the re-planned timing
                        straggles = 0
                else:
                    straggles = 0
            if self.ckpt_dir and (done or (it + 1) % self.every == 0
                                  or it + 1 == max_iters):
                self._save(it, state, bool(done))
            if done:
                break
            it += 1
        return state
