"""Parameter/activation sharding plans for the LM pillar (DESIGN.md §5).

This module is the "right data structure for the right scenario" layer of
the LM stack: it maps every parameter and activation of every registry
architecture onto the CombBLAS process grids built by ``launch/mesh.py``

  single-pod: (data=16, model=16)        — the paper's √p×√p 2D grid
  multi-pod : (pod=2, data=16, model=16) — the c×√(p/c)×√(p/c) CA 3D grid

via two exports:

  ``ShardingPlan``   — a frozen dataclass describing how the grid axes are
                       spent (data/tensor/sequence/context/expert
                       parallelism) plus the plan-side spec helpers the
                       consumers call: ``dp()``, ``cache_spec``,
                       ``act_spec``, ``ep_spec``, ``logits_spec``.
  ``spec_for_param`` — the per-parameter PartitionSpec rule table, keyed
                       by parameter path.  Every parameter family emitted
                       by ``models/model.param_shapes`` has an EXPLICIT
                       rule; an unknown path raises instead of silently
                       replicating (a mis-sharded plan corrupts the
                       §Roofline numbers, which is worse than failing
                       loudly — DESIGN §5).

Layout discipline (the Megatron/FSDP hybrid, per family):

  * ``model`` axis = tensor parallelism.  Column-parallel projections
    shard their OUTPUT dim (flattened heads × head_dim, so GQA archs with
    n_kv_heads < model_size still divide evenly); row-parallel
    projections shard their INPUT dim.  Embed/lm_head shard the padded
    vocab (vocab_padded is a multiple of 256, hence of every model size
    we build).  MoE experts live on the model axis (the expert-parallel
    axis of ``moe_block_ep``); Mamba/SSD shards inner channels and heads.
  * ``fsdp_axes`` (⊆ dp axes, the within-pod 'data' axis) = ZeRO-3: each
    family additionally shards one large non-TP dim over the data axis.
  * the 'pod' axis of the 3D mesh appears in NO parameter spec: it is
    pure data parallelism with hierarchical gradient reduction (the
    paper's reduced communicators, §3.3) — parameters are pod-replicated.

Every emitted spec is validated against the plan's axis sizes and the
parameter shape (``validate_spec``): unknown mesh axes, axes used twice,
or a sharded dim not divisible by its axis size raise ``ShardingError``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Optional

from jax.sharding import PartitionSpec as P


class ShardingError(ValueError):
    """A spec that would silently mis-shard: wrong axis, reuse, or a
    sharded dimension not divisible by the axis size."""


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _entry(axes):
    """Normalize an axis collection to a PartitionSpec entry."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """How the mesh axes are spent for one (arch × shape × mesh) cell.

    Built by ``launch/mesh.make_plan``; consumed by ``models/model.py``
    (param specs + activation constraints), ``models/layers.py`` (MoE
    dispatch), ``launch/dryrun.py`` (batch/cache input shardings) and the
    train/serve launchers.
    """
    dp_axes: tuple[str, ...]          # all data-parallel axes (pod, data)
    model_axis: str                   # tensor-parallel axis name
    model_size: int                   # size of the model axis
    fsdp_axes: tuple[str, ...]        # ⊆ dp_axes: param-sharding (ZeRO-3)
    seq_parallel: bool                # shard activation seq over model
    context_parallel: bool            # decode w/ batch < dp: shard the
    # cache SEQUENCE over the dp axes instead of the (unshardable) batch
    dp_size: int                      # product of dp axis sizes
    moe_ep: bool                      # shard_map expert-parallel dispatch
    mesh: Any = None                  # jax Mesh/AbstractMesh or None
    axis_sizes: Optional[Mapping[str, int]] = None   # name → size; derived
    # from the mesh when one is given (make_plan fills this in)

    def __post_init__(self):
        sizes = self.axis_sizes_map()
        for ax in self.fsdp_axes:
            if ax not in self.dp_axes:
                raise ShardingError(
                    f"fsdp axis {ax!r} is not a dp axis {self.dp_axes}")
        if self.model_axis in self.dp_axes:
            raise ShardingError(
                f"model axis {self.model_axis!r} overlaps dp {self.dp_axes}")
        if sizes:
            got = sizes.get(self.model_axis)
            if got is not None and got != self.model_size:
                raise ShardingError(
                    f"model_size {self.model_size} != mesh axis "
                    f"{self.model_axis!r} size {got}")
            dp = [sizes[a] for a in self.dp_axes if a in sizes]
            if len(dp) == len(self.dp_axes) and _prod(dp) != self.dp_size:
                raise ShardingError(
                    f"dp_size {self.dp_size} != product of dp axes "
                    f"{dict(zip(self.dp_axes, dp))}")

    # ---------------- axis bookkeeping ----------------
    def axis_sizes_map(self) -> dict[str, int]:
        """name → size for every mesh axis this plan can legally use."""
        if self.mesh is not None:
            return dict(self.mesh.shape)
        if self.axis_sizes is not None:
            return dict(self.axis_sizes)
        sizes = {self.model_axis: self.model_size}
        if len(self.dp_axes) == 1:
            sizes[self.dp_axes[0]] = self.dp_size
        return sizes                   # multi-dp w/o mesh: pod split unknown

    def axis_size(self, entry) -> int:
        """Total shard count of a spec entry (axis name or tuple)."""
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        sizes = self.axis_sizes_map()
        missing = [a for a in axes if a not in sizes]
        if missing:
            raise ShardingError(f"axes {missing} not on this plan's mesh "
                                f"(have {sorted(sizes)})")
        return _prod(sizes[a] for a in axes)

    def fsdp_size(self) -> int:
        return self.axis_size(_entry(self.fsdp_axes)) if self.fsdp_axes \
            else 1

    # ---------------- spec helpers (plan side) ----------------
    def dp(self):
        """Spec entry sharding a batch dim over ALL data axes."""
        return _entry(self.dp_axes)

    def fsdp(self):
        """Spec entry for the parameter-sharding (ZeRO) axes, or None."""
        return _entry(self.fsdp_axes)

    def _tp_if(self, dim_size: int):
        """Model-axis entry when the dim divides evenly, else None.

        Used only for ACTIVATION/cache layouts, where an indivisible dim
        is legitimately left whole (e.g. the MLA shared rope key has a
        single head); parameters go through the strict rule table.
        """
        return self.model_axis if dim_size % self.model_size == 0 else None

    def act_spec(self) -> P:
        """(B, S, D) activation constraint at block boundaries."""
        batch = None if self.context_parallel else self.dp()
        seq = self.model_axis if self.seq_parallel else None
        return P(batch, seq, None)

    def logits_spec(self) -> P:
        """(B, S, vocab_padded): vocab over model (vocab_padded is a
        multiple of 256, so it always divides)."""
        batch = None if self.context_parallel else self.dp()
        return P(batch, None, self.model_axis)

    def ep_spec(self) -> P:
        """(E, C, D) MoE dispatch buffer: experts over the model axis —
        the at-rest layout matching the expert weights, so the grouped
        FFN runs expert-local (padding experts make E divide)."""
        return P(self.model_axis, None, None)

    def cache_spec(self, kind: str, dims: Mapping[str, int]) -> tuple:
        """Decode-cache layout for one cache family (no leading reps dim
        — callers prepend it: ``P(None, *plan.cache_spec(...))``).

        kind='kv'      (B, S, KVH, hd)   dims: kvh, hd
        kind='kv_flat' (B, S, X)         dims: x   (MLA latent)
        kind='ssm'     (B, H, P, N)      dims: h
        kind='conv'    (B, W, C)         dims: c

        Batch shards over the dp axes; under context_parallel (decode
        with batch < dp_size) the SEQUENCE dim takes the dp axes instead
        (the §Perf cell C sequence-sharded cache).  The head-ish dim takes
        the model axis when divisible; 'kv' falls back to sharding
        head_dim when n_kv_heads < model_size (GQA), and the MLA shared
        rope key (kvh=1) lands there too.
        """
        batch = None if self.context_parallel else self.dp()
        seq = self.dp() if self.context_parallel else None
        if kind == "kv":
            kvh, hd = int(dims["kvh"]), int(dims["hd"])
            if kvh % self.model_size == 0:
                heads, head_dim = self.model_axis, None
            else:
                heads, head_dim = None, self._tp_if(hd)
            return (batch, seq, heads, head_dim)
        if kind == "kv_flat":
            return (batch, seq, self._tp_if(int(dims["x"])))
        if kind == "ssm":
            return (batch, self._tp_if(int(dims["h"])), None, None)
        if kind == "conv":
            return (batch, None, self._tp_if(int(dims["c"])))
        raise ShardingError(f"unknown cache kind {kind!r} "
                            "(want kv | kv_flat | ssm | conv)")


# --------------------------------------------------------------------------
# spec validation
# --------------------------------------------------------------------------

def validate_spec(spec: P, shape: tuple, plan: ShardingPlan,
                  path: str = "?") -> P:
    """Check one spec against the mesh axes and the array shape.

    Raises ShardingError on: rank mismatch, an axis not on the mesh, an
    axis used on two dims, or a sharded dim not divisible by the total
    shard count of its entry.  Returns the spec unchanged on success.
    """
    if len(spec) > len(shape):
        raise ShardingError(
            f"{path}: spec {spec} has more entries than shape {shape}")
    seen: list[str] = []
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            if not isinstance(ax, str):
                raise ShardingError(f"{path}: bad spec entry {entry!r}")
            if ax in seen:
                raise ShardingError(
                    f"{path}: axis {ax!r} used on two dims of {spec}")
            seen.append(ax)
        n = plan.axis_size(entry)      # raises on unknown axes
        if shape[d] % n:
            raise ShardingError(
                f"{path}: dim {d} of shape {shape} ({shape[d]}) is not "
                f"divisible by {entry!r} (size {n}) in spec {spec}")
    return spec


def validate_spec_tree(specs, shapes, plan: ShardingPlan, prefix: str = ""):
    """Validate a nested dict of specs against the matching shape tree.

    Also checks tree congruence: the two trees must have identical keys.
    """
    if isinstance(shapes, dict) != isinstance(specs, dict):
        raise ShardingError(f"{prefix or '<root>'}: tree mismatch "
                            f"({type(specs).__name__} vs "
                            f"{type(shapes).__name__})")
    if isinstance(shapes, dict):
        if set(specs) != set(shapes):
            raise ShardingError(
                f"{prefix or '<root>'}: key mismatch "
                f"{sorted(set(specs) ^ set(shapes))}")
        for k in shapes:
            validate_spec_tree(specs[k], shapes[k], plan,
                               f"{prefix}/{k}" if prefix else k)
    else:
        validate_spec(specs, tuple(shapes), plan, prefix)


# --------------------------------------------------------------------------
# per-parameter rules
# --------------------------------------------------------------------------
#
# One rule per parameter family: (tp_dim, fsdp_dim) indices into the
# UNSTACKED shape (block params carry a leading period-repeats dim that is
# never sharded — it is the lax.scan carry axis).  tp_dim takes the model
# axis; fsdp_dim takes plan.fsdp_axes.  None = that kind of sharding does
# not apply to the family.
#
#   family                         shape            tp dim     fsdp dim
#   ---------------------------------------------------------------------
#   embed / lm_head                (Vp, D)          0 (vocab)  1 (D)
#   vision_proj / frame_proj       (D, D)           1 (out)    0 (in)
#   final_norm / ln1 / ln2 / kv_ln (D,)             —          0
#   wq / wk / wv   (col-parallel)  (D, heads·hd)    1          0
#   bq / bk / bv                   (heads·hd,)      0          —
#   wo             (row-parallel)  (heads·hd, D)    0          1
#   w_dkv  (MLA down-proj)         (D, lora+rope)   1          0
#   w_ukv  (MLA up-proj)           (lora, H·(n+v))  1          0
#   in_z / in_xbc / in_dt (mamba)  (D, inner)       1          0
#   conv_w                         (width, chans)   1          —
#   A_log / dt_bias / D_skip       (H,)             0          —
#   out_proj                       (inner, D)       0          1
#   router                         (D, E)           —          0
#   we_g / we_1  (routed experts)  (E, D, F)        0 (E)      2 (F) †
#   we_2                           (E, F, D)        0 (E)      1 (F) †
#   ws_g / ws_1  (shared experts)  (Ns, D, F)       2 (F)      1 (D)
#   ws_2                           (Ns, F, D)       1 (F)      2 (D)
#   wg / w1        (col-parallel)  (D, F)           1          0
#   w2             (row-parallel)  (F, D)           0          1
#
# † under plan.moe_ep the expert weights drop their fsdp dim: shard_map
#   dispatch consumes them as P(model, None, None), and regathering an
#   fsdp-sharded F inside every layer would defeat the expert-parallel
#   regrouping (the weights stay whole per expert shard).

_TOP_RULES: dict[str, tuple] = {
    "embed":       (0, 1),
    "lm_head":     (0, 1),
    "final_norm":  (None, 0),
    "vision_proj": (1, 0),
    "frame_proj":  (1, 0),
}

_BLOCK_RULES: dict[str, tuple] = {
    # norms
    "ln1": (None, 0), "ln2": (None, 0), "kv_ln": (None, 0),
    # attention (GQA + MLA share wq/wo; flattened head dims divide the
    # model axis even when n_kv_heads < model_size)
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0),
    "bq": (0, None), "bk": (0, None), "bv": (0, None),
    "wo": (0, 1),
    "w_dkv": (1, 0), "w_ukv": (1, 0),
    # mamba2 / SSD
    "in_z": (1, 0), "in_xbc": (1, 0), "in_dt": (1, 0),
    "conv_w": (1, None),
    "A_log": (0, None), "dt_bias": (0, None), "D_skip": (0, None),
    "out_proj": (0, 1),
    # MoE
    "router": (None, 0),
    "we_g": (0, 2), "we_1": (0, 2), "we_2": (0, 1),
    "ws_g": (2, 1), "ws_1": (2, 1), "ws_2": (1, 2),
    # dense MLP (silu pair or gelu)
    "wg": (1, 0), "w1": (1, 0), "w2": (0, 1),
}

_MOE_EXPERT_PARAMS = ("we_g", "we_1", "we_2")


def spec_for_param(path: str, shape: tuple, cfg, plan: ShardingPlan) -> P:
    """PartitionSpec for one parameter of ``models/model.param_shapes``.

    path: '/'-joined tree path ('embed', 'blocks/pos3/wq', ...).
    Raises ShardingError for unknown families or indivisible layouts —
    there is deliberately no replicated fallback (DESIGN §5).
    """
    shape = tuple(shape)
    name = path.split("/")[-1]
    in_block = path.startswith("blocks/")
    rules = _BLOCK_RULES if in_block else _TOP_RULES
    if name not in rules:
        raise ShardingError(
            f"no sharding rule for parameter {path!r} (shape {shape}): "
            "add its family to dist/shardings "
            f"{'_BLOCK_RULES' if in_block else '_TOP_RULES'}")
    tp_dim, fsdp_dim = rules[name]
    lead = 1 if in_block else 0        # stacked period-repeats dim
    base = shape[lead:]
    expect = max([d for d in (tp_dim, fsdp_dim) if d is not None],
                 default=0) + 1
    if len(base) < expect:
        raise ShardingError(
            f"{path}: shape {shape} has rank {len(base)} (+{lead} stacked), "
            f"family {name!r} expects rank ≥ {expect}")

    if plan.moe_ep and name in _MOE_EXPERT_PARAMS:
        fsdp_dim = None                # see † above

    entries: list = [None] * len(shape)
    if tp_dim is not None:
        d = lead + tp_dim
        if base[tp_dim] % plan.model_size:
            raise ShardingError(
                f"{path}: dim {d} ({base[tp_dim]}) not divisible by model "
                f"axis {plan.model_axis!r} (size {plan.model_size}) — "
                f"shape {shape}")
        entries[d] = plan.model_axis
    if fsdp_dim is not None and plan.fsdp_axes:
        d = lead + fsdp_dim
        n = plan.fsdp_size()
        if base[fsdp_dim] % n:
            raise ShardingError(
                f"{path}: dim {d} ({base[fsdp_dim]}) not divisible by fsdp "
                f"axes {plan.fsdp_axes} (size {n}) — shape {shape}")
        entries[d] = plan.fsdp()
    spec = P(*entries)
    return validate_spec(spec, shape, plan, path)
