"""Wire compression: gradient reduction AND sparse exchange payloads.

Dense side (bandwidth-bound data parallelism, DESIGN.md §5) — two standard
schemes, both pytree-polymorphic and jit-safe:

- ``int8_quantize``: per-tensor symmetric int8 quantize-dequantize. The
  returned tree is float again (ready for the optimizer); the int8 payload
  is what would cross the wire, so round-trip error ≤ max|g|/254.
- ``make_topk_error_feedback``: magnitude top-k sparsification with error
  feedback [Stich et al.]: the residual (what was NOT sent) is carried in
  state and added back next step, so mass is preserved exactly:
  ``kept + residual == grad + old_residual``.

Sparse side (distributed SpGEMM value payloads, DESIGN.md §4.8):

- ``quantize_payload``/``dequantize_payload``: per-tile symmetric int8 for
  COO value buffers with nnz-aware scale (padding slots never inflate the
  scale and quantize to exact 0) plus the same error-feedback contract as
  the dense path: ``dequantize(q8, scale) + new_resid == val + resid``
  exactly, and ``|new_resid| ≤ scale/2`` per live entry (one rounding
  step). The int8 buffer is the wire payload; the scale travels with it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_payload(val, nnz=None, resid=None):
    """Per-tile symmetric int8 quantization of a COO value buffer.

    ``val`` is (..., cap) with live entries in the first ``nnz[...]`` slots
    of the last axis (all slots live when nnz is None). ``resid`` is a
    prior error-feedback residual shaped like ``val`` (added before
    quantizing). Returns ``(q8, scale, new_resid)`` where q8 is int8
    shaped like val (0 on padding), scale is val.dtype shaped val.shape
    [:-1] (the per-tile dequantization factor, max live |e|/127), and
    new_resid = (val + resid) − q8·scale, zeroed on padding.
    """
    e = val if resid is None else val + resid
    if nnz is not None:
        live = jnp.arange(val.shape[-1], dtype=jnp.int32) < nnz[..., None]
        mag = jnp.max(jnp.abs(jnp.where(live, e, 0)), axis=-1)
    else:
        live = None
        mag = jnp.max(jnp.abs(e), axis=-1)
    # the scale keeps the ORIGINAL value dtype — downstream dequantization
    # restores it even though the wire carries int8
    scale = jnp.maximum(mag / 127.0, 1e-30).astype(val.dtype)
    q8 = jnp.clip(jnp.round(e / scale[..., None]), -127, 127) \
        .astype(jnp.int8)
    if live is not None:
        q8 = jnp.where(live, q8, jnp.int8(0))
    new_resid = (e - q8.astype(val.dtype) * scale[..., None]) \
        .astype(val.dtype)
    if live is not None:
        new_resid = jnp.where(live, new_resid, jnp.zeros((), val.dtype))
    return q8, scale, new_resid


def dequantize_payload(q8, scale):
    """Inverse of :func:`quantize_payload` (scale broadcast over cap)."""
    return q8.astype(scale.dtype) * scale[..., None]


def int8_quantize(tree):
    """Symmetric per-tensor int8 round trip: dequantized float tree."""

    def one(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-30)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(x.dtype) * scale).astype(x.dtype)

    return jax.tree.map(one, tree)


def make_topk_error_feedback(frac: float = 0.01):
    """Returns (init, compress) for top-``frac`` sparsification w/ feedback.

    init(grads)            -> zero residual state (same structure)
    compress(grads, state) -> (kept, new_state); kept has ~frac·size
                              nonzeros per leaf, kept + new_state ==
                              grads + state exactly.
    """

    def init(tree):
        return jax.tree.map(jnp.zeros_like, tree)

    def compress(tree, state):
        leaves, treedef = jax.tree.flatten(tree)
        res_leaves = treedef.flatten_up_to(state)
        kept_out, res_out = [], []
        for x, r in zip(leaves, res_leaves):
            e = x + r
            k = max(1, int(round(frac * e.size)))
            mag = jnp.abs(e).ravel()
            # threshold = k-th largest magnitude; ties beyond k are kept
            # (slightly more sent, never silently dropped)
            thresh = jax.lax.top_k(mag, k)[0][-1]
            keep = jnp.abs(e) >= thresh
            kept = jnp.where(keep, e, jnp.zeros((), e.dtype))
            kept_out.append(kept)
            res_out.append(e - kept)
        return (jax.tree.unflatten(treedef, kept_out),
                jax.tree.unflatten(treedef, res_out))

    return init, compress
