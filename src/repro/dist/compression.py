"""Gradient compression for bandwidth-bound data parallelism (DESIGN.md §5).

Two standard schemes, both pytree-polymorphic and jit-safe:

- ``int8_quantize``: per-tensor symmetric int8 quantize-dequantize. The
  returned tree is float again (ready for the optimizer); the int8 payload
  is what would cross the wire, so round-trip error ≤ max|g|/254.
- ``make_topk_error_feedback``: magnitude top-k sparsification with error
  feedback [Stich et al.]: the residual (what was NOT sent) is carried in
  state and added back next step, so mass is preserved exactly:
  ``kept + residual == grad + old_residual``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_quantize(tree):
    """Symmetric per-tensor int8 round trip: dequantized float tree."""

    def one(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-30)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(x.dtype) * scale).astype(x.dtype)

    return jax.tree.map(one, tree)


def make_topk_error_feedback(frac: float = 0.01):
    """Returns (init, compress) for top-``frac`` sparsification w/ feedback.

    init(grads)            -> zero residual state (same structure)
    compress(grads, state) -> (kept, new_state); kept has ~frac·size
                              nonzeros per leaf, kept + new_state ==
                              grads + state exactly.
    """

    def init(tree):
        return jax.tree.map(jnp.zeros_like, tree)

    def compress(tree, state):
        leaves, treedef = jax.tree.flatten(tree)
        res_leaves = treedef.flatten_up_to(state)
        kept_out, res_out = [], []
        for x, r in zip(leaves, res_leaves):
            e = x + r
            k = max(1, int(round(frac * e.size)))
            mag = jnp.abs(e).ravel()
            # threshold = k-th largest magnitude; ties beyond k are kept
            # (slightly more sent, never silently dropped)
            thresh = jax.lax.top_k(mag, k)[0][-1]
            keep = jnp.abs(e) >= thresh
            kept = jnp.where(keep, e, jnp.zeros((), e.dtype))
            kept_out.append(kept)
            res_out.append(e - kept)
        return (jax.tree.unflatten(treedef, kept_out),
                jax.tree.unflatten(treedef, res_out))

    return init, compress
