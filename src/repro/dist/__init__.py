"""repro.dist — distributed training utilities for the LM pillar.

``shardings`` is the parameter/activation sharding-plan subsystem
(DESIGN.md §5): ``ShardingPlan`` + per-parameter ``spec_for_param`` rules
covering every registry architecture, consumed by launch/mesh.py,
models/model.py and launch/dryrun.py.  ``compression`` provides gradient
compression for the cross-pod reduction.
"""
from . import compression
from . import shardings
from .shardings import (ShardingError, ShardingPlan, spec_for_param,
                        validate_spec, validate_spec_tree)
