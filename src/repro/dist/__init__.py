"""repro.dist — distributed training utilities for the LM pillar.

Currently provides gradient compression (``compression``); the sharding
plan/spec module (``shardings``) referenced by launch/mesh.py and
models/model.py is future work — importing it raises ImportError, which the
dry-run reports as a skipped cell rather than silently mis-sharding.
"""
from . import compression
