"""Host-side flight recorder: spans, counters, gauges, structured events.

The observability substrate every perf PR proves its claims against
(DESIGN.md §9). Three design rules, in priority order:

1. **Near-zero overhead when disabled.** Every hook starts with one read
   of the module-level ``_enabled`` boolean and returns immediately —
   ``span()`` hands back a shared no-op singleton (no allocation beyond
   the caller's kwargs), ``counter_add``/``event`` return before touching
   any state. Attribute *formatting* never happens at record time; raw
   values are stored and stringified only at export.
2. **Host boundaries only.** Like ``robust/faults.py``, hooks are placed
   in host-level code, never inside jit/shard_map-traced functions. As a
   second line of defense, :func:`recording` (and therefore ``span``)
   checks ``jax.core.trace_state_clean()`` once per call when enabled, so
   a hook reached from inside a trace quietly no-ops instead of recording
   a meaningless trace-time duration or crashing on a Tracer.
3. **Deterministic metrics.** Counter and event *values* derive only from
   data sizes and control-flow decisions (payload bytes, retry counts,
   ladder rungs) — two identically-seeded runs produce identical counter
   totals, which the subprocess determinism test pins.

Enablement: ``REPRO_TRACE=<path>`` (Chrome-trace dump at exit, see
``export.py``) or ``REPRO_OBS=1`` (record + ``snapshot()`` only) at
import, or :func:`enable` / the scoped :func:`capture` at runtime.

This module imports nothing from ``repro`` (robust and core import us);
jax is imported lazily and only while recording.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import Any

_lock = threading.Lock()
_tls = threading.local()

_enabled = False                 # THE fast-path check — one module global
_epoch_perf = 0.0                # perf_counter() at enable-time (trace t=0)
_epoch_wall = 0.0                # matching wall-clock epoch (seconds)

# finished spans: (name, tid, t0, dur, depth, attrs)   [t0 rel. epoch_perf]
_spans: list[tuple] = []
# instant events: (name, tid, t, attrs)
_events: list[tuple] = []
# counters: monotonic totals + a (name, t, total) series for counter tracks
_counters: dict[str, float] = {}
_counter_series: list[tuple] = []
_gauges: dict[str, float] = {}

_MAX_RECORDS = 1_000_000         # backstop against unbounded growth


# --------------------------------------------------------------------------
# tracing guard (second line of defense behind host-boundary placement)
# --------------------------------------------------------------------------

_trace_pred = None


def tracing() -> bool:
    """True when called from inside jax tracing (jit/shard_map/scan)."""
    global _trace_pred
    if _trace_pred is None:
        try:
            from jax.core import trace_state_clean
            _trace_pred = trace_state_clean
        except Exception:                      # pragma: no cover - old jax
            try:
                from jax._src.core import trace_state_clean
                _trace_pred = trace_state_clean
            except Exception:
                _trace_pred = lambda: True
    return not _trace_pred()


def enabled() -> bool:
    """The raw switch (no tracing check) — cheapest possible read."""
    return _enabled


def recording() -> bool:
    """True when hooks should record: enabled AND on the host side.

    Use this to guard any host transfer done purely for observability
    (e.g. summing ``nnz`` for payload-byte counters).
    """
    return _enabled and not tracing()


# --------------------------------------------------------------------------
# spans
# --------------------------------------------------------------------------

def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


class _NoopSpan:
    """Shared do-nothing span — what ``span()`` returns when disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "t0", "depth")

    def __init__(self, name: str, attrs):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        stack = _stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:                    # exited out of order
            stack.remove(self)
        with _lock:
            if len(_spans) < _MAX_RECORDS:
                _spans.append((self.name, threading.get_ident(),
                               self.t0 - _epoch_perf, dur, self.depth,
                               self.attrs))
        return False


def span(name: str, **attrs):
    """Context manager timing one host-side region.

    ``with obs.span("spgemm2d.execute", schedule=s): ...`` — thread-safe,
    nestable (depth comes from a thread-local stack), wall-time anchored
    (the export maps the monotonic timestamps onto the wall-clock epoch).
    Returns a shared no-op when disabled or when called from inside jax
    tracing.
    """
    if not _enabled:
        return _NOOP
    if tracing():
        return _NOOP
    return _Span(name, attrs)


def timed(name: str, **attrs):
    """Decorator form of :func:`span` for whole host-level functions."""
    def deco(fn):
        import functools

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _enabled:
                return fn(*a, **kw)
            with span(name, **attrs):
                return fn(*a, **kw)
        return wrapper
    return deco


def sync(x):
    """``jax.block_until_ready(x)`` when recording (else free).

    Inside an execute span this makes the span cover device execution, not
    just async dispatch — tracing mode buys honest timings with the wait;
    disabled mode pays nothing and keeps async dispatch.
    """
    if _enabled and not tracing():
        import jax
        jax.block_until_ready(x)
    return x


# --------------------------------------------------------------------------
# metrics: counters / gauges / instant events
# --------------------------------------------------------------------------

def counter_add(name: str, value: float = 1):
    """Add to a monotonic counter (also sampled for the trace track)."""
    if not _enabled:
        return
    t = time.perf_counter() - _epoch_perf
    with _lock:
        total = _counters.get(name, 0) + value
        _counters[name] = total
        if len(_counter_series) < _MAX_RECORDS:
            _counter_series.append((name, t, total))


def gauge_set(name: str, value: float):
    if not _enabled:
        return
    t = time.perf_counter() - _epoch_perf
    with _lock:
        _gauges[name] = value
        if len(_counter_series) < _MAX_RECORDS:
            _counter_series.append((name, t, value))


def event(name: str, **attrs):
    """Record an instant structured event (planner decision, ladder rung)."""
    if not _enabled:
        return
    if tracing():
        return
    t = time.perf_counter() - _epoch_perf
    with _lock:
        if len(_events) < _MAX_RECORDS:
            _events.append((name, threading.get_ident(), t, attrs))


def counters() -> dict[str, float]:
    with _lock:
        return dict(_counters)


def events(name: str | None = None) -> list[dict]:
    """Recorded instant events as plain dicts (newest last)."""
    with _lock:
        evs = list(_events)
    out = [dict(name=n, t=t, **a) for n, _tid, t, a in evs
           if name is None or n == name]
    return out


# --------------------------------------------------------------------------
# lifecycle
# --------------------------------------------------------------------------

def enable():
    """Start recording (idempotent). The epoch anchors trace timestamps."""
    global _enabled, _epoch_perf, _epoch_wall
    if _enabled:
        return
    _epoch_perf = time.perf_counter()
    _epoch_wall = time.time()
    _enabled = True


def disable():
    global _enabled
    _enabled = False


def reset():
    """Clear every recorded span/event/counter (keeps the enabled state)."""
    with _lock:
        _spans.clear()
        _events.clear()
        _counters.clear()
        _counter_series.clear()
        _gauges.clear()


@contextlib.contextmanager
def capture():
    """Scoped recording into fresh buffers; prior state restored on exit.

    The unit-test workhorse: ``with obs.capture(): ... obs.snapshot()``
    never leaks spans into (or inherits spans from) the surrounding run.
    Yields the ``repro.obs`` package so callers can ``rec.snapshot()``,
    ``rec.trace_events()``, ``rec.write_trace(path)`` etc.
    """
    import sys
    global _enabled
    with _lock:
        saved = (_enabled, list(_spans), list(_events), dict(_counters),
                 list(_counter_series), dict(_gauges))
        _spans.clear()
        _events.clear()
        _counters.clear()
        _counter_series.clear()
        _gauges.clear()
    _enabled = False
    enable()
    try:
        yield sys.modules[__package__]
    finally:
        with _lock:
            _enabled = saved[0]
            _spans[:] = saved[1]
            _events[:] = saved[2]
            _counters.clear()
            _counters.update(saved[3])
            _counter_series[:] = saved[4]
            _gauges.clear()
            _gauges.update(saved[5])


# --------------------------------------------------------------------------
# aggregation: snapshot / coverage
# --------------------------------------------------------------------------

def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def snapshot() -> dict[str, Any]:
    """Plain-dict summary: per-site span stats + counter/gauge totals.

    ``{"spans": {site: {count, total_us, p50_us, p99_us}},
       "counters": {...}, "gauges": {...}, "events": {name: count},
       "deadline": {site: {n, median_s, budget_s, trips}}}``

    This is what ``benchmarks/run.py --json`` embeds as ``trace_summary``
    in every ``BENCH_*.json``. The deadline section is pulled live from
    ``robust/deadline.stats`` (lazy import — obs stays dependency-free).
    """
    with _lock:
        spans = list(_spans)
        evs = list(_events)
        cts = dict(_counters)
        gs = dict(_gauges)
    per_site: dict[str, list[float]] = {}
    for name, _tid, _t0, dur, _depth, _attrs in spans:
        per_site.setdefault(name, []).append(dur * 1e6)
    span_stats = {}
    for name, durs in sorted(per_site.items()):
        durs.sort()
        span_stats[name] = {
            "count": len(durs),
            "total_us": round(sum(durs), 1),
            "p50_us": round(_percentile(durs, 0.50), 1),
            "p99_us": round(_percentile(durs, 0.99), 1),
        }
    ev_counts: dict[str, int] = {}
    for name, _tid, _t, _attrs in evs:
        ev_counts[name] = ev_counts.get(name, 0) + 1
    out = {"spans": span_stats, "counters": cts, "gauges": gs,
           "events": ev_counts}
    dl = _deadline_stats()
    if dl:
        out["deadline"] = dl
    return out


def _deadline_stats() -> dict:
    try:
        from repro.robust import deadline
    except Exception:                          # pragma: no cover
        return {}
    g = deadline.active_guard()
    if g is None:
        return {}
    return {site: g.stats(site) for site in g.sites()}


def coverage(parent: str) -> float:
    """Fraction of ``parent`` span time covered by directly-nested spans.

    For every finished span named ``parent``, sums the durations of spans
    one level deeper on the same thread whose start falls inside the
    parent's window, and divides by the summed parent durations. This is
    the self-check behind the "per-stage spans account for >=90% of each
    swept SpGEMM call" acceptance gate.
    """
    with _lock:
        spans = list(_spans)
    parents = [(tid, t0, dur, depth) for name, tid, t0, dur, depth, _ in spans
               if name == parent]
    if not parents:
        return 0.0
    total = sum(p[2] for p in parents)
    covered = 0.0
    for name, tid, t0, dur, depth, _ in spans:
        if name == parent:
            continue
        for ptid, pt0, pdur, pdepth in parents:
            if tid == ptid and depth == pdepth + 1 \
                    and pt0 <= t0 and t0 + dur <= pt0 + pdur + 1e-9:
                covered += dur
                break
    return covered / max(total, 1e-12)


def _raw_records():
    """(spans, events, counter_series, epoch_wall) for the trace export."""
    with _lock:
        return (list(_spans), list(_events), list(_counter_series),
                _epoch_wall)


# --------------------------------------------------------------------------
# environment init (REPRO_TRACE=<path> | REPRO_OBS=1)
# --------------------------------------------------------------------------

def _env_init():
    path = os.environ.get("REPRO_TRACE", "").strip()
    if path:
        enable()
        import atexit

        def _dump(path=path):
            # lazy: export imports this module, so importing it here at
            # module-init time would be circular
            from . import export
            export.write_trace(path)

        atexit.register(_dump)
    elif os.environ.get("REPRO_OBS", "").strip() not in ("", "0"):
        enable()


_env_init()
