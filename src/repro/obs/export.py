"""Chrome-trace (chrome://tracing / Perfetto) JSON export.

One file per process: ``REPRO_TRACE=<path>`` registers an atexit dump, and
``write_trace(path)`` can be called explicitly (benchmarks do, so a trace
exists even if the process is killed later). Format reference: the Trace
Event Format doc — we emit

  * ``ph:"X"`` complete events for spans (``ts``/``dur`` in µs),
  * ``ph:"C"`` counter events, one track per counter name,
  * ``ph:"i"`` instant events for planner decisions / ladder rungs,
  * ``ph:"M"`` metadata naming the process and threads.

Timestamps are relative to the recorder's enable-time epoch; the absolute
wall-clock epoch is stored in ``otherData.epoch_unix_s`` so multi-process
traces (the dist_bench subprocesses) can be aligned offline.
"""
from __future__ import annotations

import json
import os

from . import recorder


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def trace_events() -> list[dict]:
    """The traceEvents list (split out for tests and for merging)."""
    spans, events, series, _epoch = recorder._raw_records()
    pid = os.getpid()
    tid_map: dict[int, int] = {}

    def tid_of(t):
        return tid_map.setdefault(t, len(tid_map))

    out: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": f"repro[{pid}]"},
    }]
    for name, tid, t0, dur, _depth, attrs in spans:
        out.append({
            "name": name, "cat": "span", "ph": "X",
            "ts": round(t0 * 1e6, 3), "dur": round(dur * 1e6, 3),
            "pid": pid, "tid": tid_of(tid),
            "args": {k: _jsonable(v) for k, v in attrs.items()},
        })
    for name, tid, t, attrs in events:
        out.append({
            "name": name, "cat": "event", "ph": "i", "s": "t",
            "ts": round(t * 1e6, 3), "pid": pid, "tid": tid_of(tid),
            "args": {k: _jsonable(v) for k, v in attrs.items()},
        })
    for name, t, total in series:
        out.append({
            "name": name, "cat": "counter", "ph": "C",
            "ts": round(t * 1e6, 3), "pid": pid, "tid": 0,
            "args": {"value": _jsonable(total)},
        })
    for raw, small in tid_map.items():
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": small, "args": {"name": f"thread-{raw}"}})
    return out


def write_trace(path: str):
    """Dump everything recorded so far as a Chrome-trace JSON file."""
    _spans, _events, _series, epoch = recorder._raw_records()
    doc = {
        "traceEvents": trace_events(),
        "displayTimeUnit": "ms",
        "otherData": {"epoch_unix_s": epoch, "pid": os.getpid()},
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path
