"""repro.obs — zero-dependency host-side flight recorder (DESIGN.md §9).

Spans + counters/gauges/events + Chrome-trace export. Off by default;
``REPRO_TRACE=<path>`` enables recording and dumps a Perfetto-loadable
trace at exit, ``REPRO_OBS=1`` enables recording without a dump (the
``snapshot()``-only mode the benchmark subprocesses use).
"""
from .export import trace_events, write_trace
from .recorder import (capture, counter_add, counters, coverage, disable,
                       enable, enabled, event, events, gauge_set, recording,
                       reset, snapshot, span, sync, timed, tracing)

__all__ = [
    "capture", "counter_add", "counters", "coverage", "disable", "enable",
    "enabled", "event", "events", "gauge_set", "recording", "reset",
    "snapshot", "span", "sync", "timed", "tracing", "trace_events",
    "write_trace",
]
