"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Kernels (each validated in interpret mode against the pure-jnp oracle in
ref.py; on-TPU they are swapped in via ops.py):

  semiring_matmul  dense-tile semiring contraction — the TPU realization of
                   the paper's "hash-table" local SpGEMM accumulator
                   (DESIGN.md §4.2): MXU path for (+,×), VPU path for
                   min-plus / max-min / or-and
  segreduce        segmented semiring reduce (DESIGN.md §4.4) — the merge
                   engine's reduction stage; VMEM-resident output tiles as
                   running accumulators, registered behind
                   core.semiring.segment_reduce for tagged monoids
  bsr_spmm         block-sparse (ELL-blocked) × dense SpMM — the paper's
                   SpMM offload (§5) and the MoE grouped-matmul engine
  flash_attention  causal online-softmax attention (prefill hot-spot)
  ssd_chunk        Mamba2 SSD intra-chunk quadratic kernel

The paper's GPU offload policy (§5: "devices handle local multiplies, host
handles communication/merge; arithmetic-semiring only on device") maps to:
XLA handles collectives + sparse merges, these kernels handle dense-tile
contractions; non-jnp-expressible semirings fall back to the pure-JAX path.
"""
