"""Pallas TPU kernel: blocked-ELL sparse × dense SpMM (DESIGN.md §4.2/§5).

Format: block-row r stores up to K dense (bm × bk) blocks with their block
-column ids (−1 = padding) — an ELL layout at BLOCK granularity. This is
the TPU-native answer to DCSC/CSC: regular strides for the sequencer, MXU
-aligned dense blocks, sparsity expressed block-wise. The same kernel is
the MoE expert engine: a block-diagonal A makes it a grouped matmul.

Grid: (R, N/bn, K) — for each block-row and output column tile, scan the
stored blocks; the block-column id (scalar-prefetched from SMEM) drives the
x BlockSpec index_map, so only the needed x tile is pulled into VMEM per
step. Padding blocks contribute via a zeroed multiplicand (branchless).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compat import tpu_compiler_params


def _kernel(cols_ref, vals_ref, x_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = cols_ref[pl.program_id(0), k]
    blk = vals_ref[...]                    # (bm, bk)
    xt = x_ref[...]                        # (bk, bn)
    contrib = jnp.dot(blk, xt, preferred_element_type=o_ref.dtype)
    o_ref[...] += jnp.where(c >= 0, contrib, 0.0)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def bsr_spmm(block_cols, block_vals, x, *, bn: int = 128,
             interpret: bool = True):
    """y = A @ x. block_cols: (R,K) i32; block_vals: (R,K,bm,bk);
    x: (n_cols, n) with n_cols % bk == 0. Returns (R*bm, n)."""
    R, K, bm, bk = block_vals.shape
    n_cols, n = x.shape
    assert n_cols % bk == 0
    bn = min(bn, n)
    assert n % bn == 0
    out_dtype = jnp.promote_types(block_vals.dtype, x.dtype)
    grid = (R, n // bn, K)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, None, bm, bk),
                             lambda r, j, k, cols: (r, k, 0, 0)),
                # x block chosen by the scalar-prefetched block-column id;
                # clamp padding (-1) to 0 — the kernel zeroes it out
                pl.BlockSpec((bk, bn),
                             lambda r, j, k, cols:
                             (jnp.maximum(cols[r, k], 0), j)),
            ],
            out_specs=pl.BlockSpec((None, bm, bn),
                                   lambda r, j, k, cols: (r, 0, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, bm, n), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_cols, block_vals, x).reshape(R * bm, n)


def to_blocked_ell(dense, bm: int, bk: int, max_blocks: int | None = None):
    """Host helper: dense (M, N) -> (block_cols, block_vals)."""
    import numpy as np
    M, N = dense.shape
    assert M % bm == 0 and N % bk == 0
    R, C = M // bm, N // bk
    blocks = dense.reshape(R, bm, C, bk).transpose(0, 2, 1, 3)
    nz = np.asarray([[np.any(blocks[r, c]) for c in range(C)]
                     for r in range(R)])
    K = max_blocks or max(int(nz.sum(1).max()), 1)
    cols = np.full((R, K), -1, np.int32)
    vals = np.zeros((R, K, bm, bk), dense.dtype)
    for r in range(R):
        js = np.nonzero(nz[r])[0][:K]
        cols[r, :len(js)] = js
        for t, c in enumerate(js):
            vals[r, t] = blocks[r, c]
    return cols, vals
