"""jit'd dispatch wrappers: Pallas kernel on TPU, oracle elsewhere.

``use_pallas=None`` auto-detects the backend. CPU runs use interpret mode
only in tests (it is a correctness tool, not a fast path).
"""
from __future__ import annotations

import jax

from . import ref
from .bsr_spmm import bsr_spmm as _bsr_pallas, to_blocked_ell
from .flash_attention import flash_attention as _fa_pallas
from .semiring_matmul import semiring_matmul as _sm_pallas
from .ssd_chunk import ssd_chunk as _ssd_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


def semiring_matmul(a, b, kind="plus_times", use_pallas=None, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()):
        return _sm_pallas(a, b, kind=kind, interpret=not _on_tpu(), **kw)
    return ref.semiring_matmul(a, b, kind)


def bsr_spmm(block_cols, block_vals, x, use_pallas=None, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()):
        return _bsr_pallas(block_cols, block_vals, x,
                           interpret=not _on_tpu(), **kw)
    return ref.bsr_spmm(block_cols, block_vals, x, x.shape[0])


def flash_attention(q, k, v, causal=True, use_pallas=None, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()):
        return _fa_pallas(q, k, v, causal=causal, interpret=not _on_tpu(),
                          **kw)
    return ref.flash_attention(q, k, v, causal)


def ssd_chunk(xc, dtc, A, Bc, Cc, use_pallas=None, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()):
        return _ssd_pallas(xc, dtc, A, Bc, Cc, interpret=not _on_tpu(), **kw)
    import jax.numpy as jnp
    ys, sts = [], []
    for g in range(xc.shape[0]):
        y, st = ref.ssd_chunk_diag(xc[g], dtc[g], A, Bc[g], Cc[g])
        ys.append(y)
        sts.append(st)
    return jnp.stack(ys), jnp.stack(sts)
