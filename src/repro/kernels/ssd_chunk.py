"""Pallas TPU kernel: Mamba2 SSD intra-chunk contraction.

One grid step processes one (batch, chunk) pair entirely in VMEM:
  y_diag[q,h,p] = Σ_{k≤q} (C_q·B_k) · exp(ΔAcum_q − ΔAcum_k) · dt_k · x[k,h,p]
  state[h,p,n] = Σ_k B_k ⊗ (exp(ΔAcum_last − ΔAcum_k)·dt_k·x[k,h,p])

The (q×q) score matrix C·Bᵀ is one MXU matmul; the decay kernel L is a
VPU exp of a cumulative-sum difference. The inter-chunk state recurrence
stays outside (a lax.scan over tiny (H,P,N) states — latency-bound, not
worth a kernel). Heads are mapped to the grid so each program's working
set is (chunk × P) — VMEM-sized by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (q,)
    A = a_ref[0]                                     # scalar decay
    Bm = b_ref[0].astype(jnp.float32)                # (q, N)
    Cm = c_ref[0].astype(jnp.float32)                # (q, N)
    q = x.shape[0]
    dA = dt * A
    dA_cum = jnp.cumsum(dA)
    seg = dA_cum[:, None] - dA_cum[None, :]          # (q, q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(iq >= ik, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * L * dt[None, :]                     # (q, q)
    y_ref[0, :, 0, :] = jax.lax.dot(
        w, x, preferred_element_type=jnp.float32).astype(y_ref.dtype)
    decay_last = jnp.exp(dA_cum[-1] - dA_cum) * dt   # (q,)
    xw = x * decay_last[:, None]                     # (q, P)
    st = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    st_ref[0, 0] = st                                # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xc, dtc, A, Bc, Cc, *, interpret: bool = True):
    """Intra-chunk SSD over all (batch·chunk, head) pairs.

    xc: (G, q, H, P); dtc: (G, q, H); A: (H,); Bc/Cc: (G, q, N) where
    G = batch·num_chunks. Returns (y (G, q, H, P), states (G, H, P, N)).
    """
    G, q, H, P = xc.shape
    N = Bc.shape[-1]
    grid = (G, H)
    y, st = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, q, 1), lambda g, h: (g, 0, h)),
            pl.BlockSpec((1,), lambda g, h: (h,)),
            pl.BlockSpec((1, q, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, q, N), lambda g, h: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda g, h: (g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((G, H, P, N), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc)
    return y, st
