"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------- semiring matmul ----------------

def semiring_matmul(a, b, kind: str = "plus_times"):
    """C[i,j] = add_k mul(a[i,k], b[k,j]) for the supported kernel algebras."""
    if kind == "plus_times":
        return a @ b
    if kind == "min_plus":
        return jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    if kind == "max_min":
        return jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
    if kind == "or_and":
        return jnp.any(a[:, :, None] & b[None, :, :], axis=1)
    raise ValueError(kind)


# ---------------- blocked-ELL SpMM ----------------

def bsr_spmm(block_cols, block_vals, x, n_cols: int):
    """y = A @ x for A in blocked-ELL format.

    block_cols: (R, K) int32 — block-column index of each stored block of
                block-row r, -1 = padding.
    block_vals: (R, K, bm, bk) — the dense blocks.
    x: (n_cols, n) dense.   Returns (R*bm, n).
    """
    R, K, bm, bk = block_vals.shape
    n = x.shape[1]
    y = jnp.zeros((R, bm, n), jnp.promote_types(block_vals.dtype, x.dtype))
    for k in range(K):
        cols = block_cols[:, k]                       # (R,)
        xb = x.reshape(-1, bk, n)[jnp.clip(cols, 0, x.shape[0] // bk - 1)]
        contrib = jnp.einsum("rmk,rkn->rmn", block_vals[:, k], xb)
        y = y + jnp.where((cols >= 0)[:, None, None], contrib, 0)
    return y.reshape(R * bm, n)


# ---------------- flash attention ----------------

def flash_attention(q, k, v, causal: bool = True):
    """Reference softmax attention. q/k/v: (B, S, H, d)."""
    B, S, H, d = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd",
                      p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------- SSD intra-chunk ----------------

def ssd_chunk_diag(xc, dtc, A, Bc, Cc):
    """Intra-chunk SSD contribution (one chunk).

    xc: (q, H, P); dtc: (q, H); A: (H,); Bc, Cc: (q, N).
    Returns (y_diag (q, H, P), state (H, P, N)).
    """
    q = xc.shape[0]
    dA = dtc * A[None, :]                          # (q, H)
    dA_cum = jnp.cumsum(dA, axis=0)
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]  # (q, q, H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(causal[:, :, None], jnp.exp(seg), 0.0)
    scores = Cc @ Bc.T                             # (q, q)
    y = jnp.einsum("qk,qkh,kh,khp->qhp", scores, L, dtc, xc)
    decay_last = jnp.exp(dA_cum[-1:, :] - dA_cum)  # (q, H)
    state = jnp.einsum("kn,kh,kh,khp->hpn", Bc, decay_last, dtc, xc)
    return y, state
