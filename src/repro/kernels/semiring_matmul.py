"""Pallas TPU kernel: dense-tile semiring matmul (DESIGN.md §4.2).

The paper's hash-table local SpGEMM accumulates scattered products in O(1)
per product. TPUs have no efficient scatter, but the MXU/VPU make a dense
VMEM accumulator tile the equivalent structure: the (i,j) slot of the tile
*is* the hash bucket, collision-free by construction.

Grid: (M/bm, N/bn, K/bk), K innermost so the output tile stays resident in
VMEM across the contraction (revisits = 1). The accumulator lives in the
output ref (dimension_semantics mark K as a reduction axis).

Algebras: 'plus_times' uses the MXU (jnp.dot); 'min_plus', 'max_min',
'or_and' run on the VPU via broadcast-reduce over the K tile. Anything
outside this set falls back to the pure-JAX path (the paper's
"arithmetic-only on device" rule, §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compat import tpu_compiler_params

IDENTITY = dict(plus_times=0.0, min_plus=jnp.inf, max_min=-jnp.inf,
                or_and=False)


def _kernel(a_ref, b_ref, o_ref, *, kind: str, bk: int):
    k = pl.program_id(2)
    a = a_ref[...]
    b = b_ref[...]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, IDENTITY[kind])

    if kind == "plus_times":
        o_ref[...] += jnp.dot(a, b, preferred_element_type=o_ref.dtype)
    elif kind == "min_plus":
        cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
        o_ref[...] = jnp.minimum(o_ref[...], cand)
    elif kind == "max_min":
        cand = jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
        o_ref[...] = jnp.maximum(o_ref[...], cand)
    elif kind == "or_and":
        cand = jnp.any(a[:, :, None] & b[None, :, :], axis=1)
        o_ref[...] = jnp.logical_or(o_ref[...], cand)
    else:
        raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=("kind", "bm", "bn", "bk",
                                             "interpret"))
def semiring_matmul(a, b, *, kind: str = "plus_times", bm: int = 128,
                    bn: int = 128, bk: int = 128, interpret: bool = True):
    """C = A ⊕.⊗ B with MXU-aligned VMEM tiling. A: (M,K), B: (K,N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        "pad operands to the block size"
    if kind == "plus_times":
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    elif kind == "or_and":
        out_dtype = jnp.bool_
    else:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, kind=kind, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
