"""Pallas TPU kernel: segmented semiring reduce (DESIGN.md §4.4).

The merge engine's last stage — and every ``COO.reduce`` — is a segmented
reduction of a value stream by sorted segment ids. XLA's ``segment_sum``
lowers to scatter-add, which TPUs emulate serially; this kernel instead
keeps a tile of the *output* VMEM-resident as the running accumulator and
streams the input past it:

  grid = (S/bs, N/bn) with the input dimension innermost, so output tile j
  stays in VMEM across the whole input sweep (revisits = 1, like the
  matmul kernel's K axis). ``@pl.when(k == 0)`` initializes the
  accumulator to the monoid identity; a second ``@pl.when`` skips input
  blocks whose id range cannot touch this output tile — for the sorted
  streams the merge engine produces, each input block intersects O(1)
  output tiles, so the sweep does O(N·bs + S·bn) work, not O(N·S).

Per surviving (tile, block) pair the segment combine is a broadcast
compare-and-reduce on the VPU (no scatter): hit[t, i] = (ids[i] == t),
acc[t] ⊕= reduce_i(values[i] where hit).

Only tagged monoids ('sum'/'min'/'max') are supported — the kernel must
name a VPU reduction. ``register()`` installs it as the backend behind
``core.semiring.segment_reduce``; anything it cannot take (untagged
monoids, vector-valued entries) falls through to the pure-JAX path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.compat import tpu_compiler_params

_IDENT = dict(sum=0, min=float("inf"), max=float("-inf"))


def _extreme(tag: str, dtype) -> jnp.ndarray:
    """Accumulator fill: 0 for sum, the dtype extreme for min/max."""
    if tag == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.bool_):
        return jnp.asarray(tag != "max", dtype)   # lor: False, land: True
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.asarray(info.max if tag == "min" else info.min, dtype)
    return jnp.asarray(_IDENT[tag], dtype)


def _kernel(s_ref, v_ref, o_ref, t_ref, *, tag: str, bs: int):
    k = pl.program_id(1)
    j = pl.program_id(0)
    t0 = j * bs

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _extreme(tag, o_ref.dtype))
        t_ref[...] = jnp.zeros_like(t_ref)

    s = s_ref[...]
    v = v_ref[...]

    # sorted ids ⇒ this block touches segment range [min(s), max(s)] only;
    # skip blocks that cannot intersect the resident output tile
    @pl.when((jnp.min(s) < t0 + bs) & (jnp.max(s) >= t0))
    def _accumulate():
        bn = s.shape[0]
        tids = t0 + jax.lax.broadcasted_iota(jnp.int32, (bs, bn), 0)
        hit = tids == s[None, :]
        t_ref[...] = t_ref[...] + jnp.sum(hit.astype(jnp.int32), axis=1)
        fill = _extreme(tag, v.dtype)
        cand = jnp.where(hit, v[None, :], fill)
        if tag == "sum":
            o_ref[...] = o_ref[...] + jnp.sum(cand, axis=1)
        elif tag == "min":
            o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cand, axis=1))
        elif tag == "max":
            o_ref[...] = jnp.maximum(o_ref[...], jnp.max(cand, axis=1))
        else:  # pragma: no cover - guarded by the wrapper
            raise ValueError(tag)


@functools.partial(jax.jit, static_argnames=("num_segments", "tag",
                                             "identity", "bs", "bn",
                                             "interpret"))
def segment_reduce_pallas(values, seg_ids, num_segments: int, tag: str,
                          *, identity=None, bs: int = 256, bn: int = 256,
                          interpret: bool = True):
    """Segmented reduce of a SORTED id stream under a tagged monoid.

    ids outside [0, num_segments) are dropped. Untouched segments hold
    ``identity`` (the monoid's declared identity — which may differ from
    the dtype extreme, e.g. MAX_INT's -(2^31)+1) for min/max, and 0 for
    sum, exactly matching ``core.semiring.segment_reduce``. The kernel
    accumulates against dtype extremes and counts touches; the identity
    substitution happens here, so touched segments keep their true
    reduction even when values lie below the declared identity.
    """
    assert values.ndim == 1, "kernel path is scalar-valued"
    assert tag in ("sum", "min", "max"), tag
    n = values.shape[0]
    s = int(num_segments)
    if s == 0:
        return jnp.zeros((0,), values.dtype)
    bs = min(bs, max(s, 8))
    bn = min(bn, max(n, 8))
    sp = -(-s // bs) * bs
    np_ = -(-n // bn) * bn
    fill = _extreme(tag, values.dtype)
    v = jnp.concatenate([values, jnp.full((np_ - n,), fill, values.dtype)]) \
        if np_ != n else values
    # out-of-range and padding ids -> sp (never matches a tile id)
    ids = jnp.where((seg_ids >= 0) & (seg_ids < s),
                    seg_ids.astype(jnp.int32), sp)
    ids = jnp.concatenate([ids, jnp.full((np_ - n,), sp, jnp.int32)]) \
        if np_ != n else ids
    grid = (sp // bs, np_ // bn)
    out, touched = pl.pallas_call(
        functools.partial(_kernel, tag=tag, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda j, k: (k,)),
            pl.BlockSpec((bn,), lambda j, k: (k,)),
        ],
        out_specs=[pl.BlockSpec((bs,), lambda j, k: (j,)),
                   pl.BlockSpec((bs,), lambda j, k: (j,))],
        out_shape=[jax.ShapeDtypeStruct((sp,), values.dtype),
                   jax.ShapeDtypeStruct((sp,), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, v)
    out = out[:s]
    if tag != "sum":
        ident = jnp.asarray(fill if identity is None else identity,
                            values.dtype)
        out = jnp.where(touched[:s] > 0, out, ident)
    return out


# --------------------------------------------------------------------------
# segment_reduce backend registration (core.semiring dispatch)
# --------------------------------------------------------------------------

def _backend(values, seg_ids, num_segments, tag, identity, *, interpret):
    """Adapter: returns None for inputs the kernel does not take, which
    makes ``segment_reduce`` fall through to its pure-JAX paths."""
    if values.ndim != 1 or tag not in ("sum", "min", "max"):
        return None
    if jnp.issubdtype(values.dtype, jnp.bool_) and tag == "sum":
        return None
    ident = None if tag == "sum" else identity
    if ident is not None:
        if not isinstance(ident, (int, float, bool)):
            return None                  # identity must be a static scalar
        if jnp.issubdtype(values.dtype, jnp.integer) and \
                not math.isfinite(ident):
            return None                  # inf-identity monoid on int values
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return segment_reduce_pallas(values, seg_ids, int(num_segments), tag,
                                 identity=ident, interpret=bool(interpret))


def register(*, interpret: bool | None = None) -> None:
    """Install the Pallas kernel behind ``core.semiring.segment_reduce``.

    ``interpret=None`` resolves at call time: compiled on TPU, interpreter
    elsewhere (the interpreter is for validation, not speed — automatic
    registration, via semiring's lazy backend resolution, happens only on
    TPU or under REPRO_SEGREDUCE=pallas).
    """
    from ..core import semiring
    semiring.register_segment_reduce_backend(
        functools.partial(_backend, interpret=interpret))


def unregister() -> None:
    from ..core import semiring
    semiring.register_segment_reduce_backend(None)
