"""Pallas TPU kernel: causal flash attention (online softmax).

Grid: (B·H, S/bq, S/bkv) with the KV axis innermost ('arbitrary'); running
max/denominator live in VMEM scratch, the output tile is rescaled in place.
Block-causal skip: KV tiles strictly above the diagonal contribute nothing
and are branchlessly masked (on TPU the grid itself cannot be triangular;
masked tiles still cost MXU issue — the §Perf log quantifies the 2× and
the pure-JAX twin in models/layers.py mirrors the same structure).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                              # (bq, d)
    k = k_ref[0]                              # (bkv, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bkv: int = 128, interpret: bool = True):
    """q/k/v: (B, S, H, d) — same-head attention (repeat KV for GQA first).

    Returns (B, S, H, d)."""
    B, S, H, d = q.shape
    bq, bkv = min(bq, S), min(bkv, S)
    assert S % bq == 0 and S % bkv == 0
    scale = 1.0 / np.sqrt(d)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    grid = (B * H, S // bq, S // bkv)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bkv=bkv, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running max, denominator, f32 accumulator
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, d).transpose(0, 2, 1, 3)
