"""repro.serve — KV/state-cache decode and prefill."""
from .decode import make_serve_step, make_prefill, greedy_generate
