"""Serving: prefill + single-token decode with KV / SSM-state caches.

``serve_step`` is what decode_32k / long_500k shapes lower: ONE new token
per sequence against a seq_len-deep cache. For attention archs the cache is
(K, V) per layer; MLA caches the compressed latent (kv_lora + rope key —
the DeepSeek-V2 memory win); SSM archs cache a constant-size recurrent
state (why long_500k is SSM/hybrid-only).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def make_serve_step(model):
    """serve_step(params, caches, tokens(B,1)|features, offset(B,)) ->
    (next_logits (B, vocab_padded), new_caches)"""

    def serve_step(params, caches, tokens, offset):
        batch = dict(tokens=tokens, offset=offset)
        logits, _, new_caches = model.forward(params, batch, caches=caches,
                                              remat=False)
        return logits[:, -1, :], new_caches

    return serve_step


def make_prefill(model):
    """prefill(params, caches, tokens(B,S)) -> (last_logits, caches)."""

    def prefill(params, caches, tokens):
        B = tokens.shape[0]
        offset = jnp.zeros((B,), jnp.int32)
        batch = dict(tokens=tokens, offset=offset)
        logits, _, new_caches = model.forward(params, batch, caches=caches,
                                              remat=False)
        return logits[:, -1, :], new_caches

    return prefill


def greedy_generate(model, params, prompt, max_len: int, gen_tokens: int):
    """Host loop: prefill the prompt then greedy-decode ``gen_tokens``."""
    B, S = prompt.shape
    caches = model.init_cache(B, max_len)
    prefill = jax.jit(make_prefill(model))
    step = jax.jit(make_serve_step(model))
    logits, caches = prefill(params, caches, prompt)
    out = [jnp.argmax(logits, -1)[:, None]]
    pos = S
    for _ in range(gen_tokens - 1):
        tok = out[-1].astype(jnp.int32)
        offset = jnp.full((B,), pos, jnp.int32)
        logits, caches = step(params, caches, tok, offset)
        out.append(jnp.argmax(logits, -1)[:, None])
        pos += 1
    return jnp.concatenate(out, axis=1)
