"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887].

Jamba block structure: period 8 with the attention layer at offset 4
(1 attention : 7 mamba), MoE replacing the dense MLP every 2nd layer.
The paper uses Mamba-1 mixers; we use the Mamba2/SSD mixer (state 128,
headdim 64) — noted as a deviation in DESIGN.md §Arch-applicability.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", kind="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_every=2,
    attn_period=8, attn_offset=4,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
    rope_theta=1e6,
).validate()

SMOKE = CONFIG.scaled(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=512, n_experts=4,
                      top_k=2, ssm_state=16, ssm_headdim=8, ssm_chunk=16, capacity_factor=8.0)
