"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].

Assignment note: the spec line says "MoE 64e top-6" while its prose note
says "160 routed" (that is DeepSeek-V2-full's count). We follow the
structured numbers — 64 routed, top-6, 2 shared — which matches the
released DeepSeek-V2-Lite. All layers are MoE per the assigned config
(HF's first-dense-layer exception is noted in DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", kind="decoder",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    use_mla=True, kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128,
    d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6, moe_every=1,
    rope_theta=1e4,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16,
                      v_head_dim=16, d_ff=32, vocab=512, n_experts=8,
                      n_shared_experts=1, top_k=2, capacity_factor=8.0)
