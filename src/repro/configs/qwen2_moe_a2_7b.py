"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts are padded to 64 for 16-way expert parallelism (router
logits for padding experts are masked to -inf — they never receive tokens).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", kind="decoder",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    n_experts=60, n_shared_experts=4, top_k=4, moe_every=1,
    rope_theta=1e6,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=32, vocab=512, n_experts=8,
                      n_shared_experts=2, top_k=2, capacity_factor=8.0)
