"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: 64 layers of Mamba2 mixers (d_inner = 2·d_model = 5120,
80 heads × headdim 64, state 128, chunked SSD scan). No MLP sublayer
(Mamba2 convention). Sub-quadratic ⇒ runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", kind="ssm",
    n_layers=64, d_model=2560, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128, d_conv=4,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, vocab=512, ssm_state=16,
                      ssm_headdim=8, ssm_chunk=16)
