"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only per the assignment; the vision frontend is a STUB
(input_specs provide precomputed patch embeddings; a learned projector maps
them into the token stream at vision_mask positions).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", kind="decoder",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), frontend="vision_patches",
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=512,
                      mrope_sections=(2, 3, 3))
