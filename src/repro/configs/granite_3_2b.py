"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", kind="decoder",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=49155, rope_theta=1e4, tie_embeddings=True,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=512)
