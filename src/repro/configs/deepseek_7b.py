"""deepseek-7b [dense] — llama-arch, MHA (kv == heads) [arXiv:2401.02954]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", kind="decoder",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=102400, rope_theta=1e4,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=160, vocab=512)
