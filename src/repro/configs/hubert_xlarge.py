"""hubert-xlarge [audio] — encoder-only [arXiv:2106.07447].

Backbone only: the conv waveform frontend is a STUB (input_specs provide
precomputed frame embeddings (B, T, d_model)). Training objective is
masked-unit prediction over the 504-unit codebook at masked frames.
Encoder-only ⇒ no decode shapes (skipped per the assignment).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", kind="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, mlp_act="gelu", causal=False,
    frontend="audio_frames", mask_prob=0.08,
).validate()

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=64)
