"""Architecture registry + (arch × input-shape) cell logic.

SHAPES (assignment):
  train_4k     seq 4,096   global_batch 256   lowers train_step
  prefill_32k  seq 32,768  global_batch 32    lowers prefill (forward)
  decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 token,
                                              KV cache depth = seq)
  long_500k    seq 524,288 global_batch 1     lowers serve_step; requires
                                              sub-quadratic context (SSM /
                                              hybrid only)

Cell skips (DESIGN.md §Arch-applicability):
  - long_500k skipped for pure full-attention archs (7 of 10)
  - encoder-only (hubert) has no decode: decode_32k + long_500k skipped
  ⇒ 31 valid cells.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2-72b", "deepseek-7b", "granite-3-2b", "deepseek-67b",
    "jamba-1.5-large-398b", "qwen2-vl-7b", "qwen2-moe-a2.7b",
    "deepseek-v2-lite-16b", "hubert-xlarge", "mamba2-2.7b",
]

_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "deepseek-7b": "deepseek_7b",
    "granite-3-2b": "granite_3_2b",
    "deepseek-67b": "deepseek_67b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-2.7b": "mamba2_2_7b",
}

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke(arch: str):
    return _module(arch).SMOKE


def cell_step_kind(arch: str, shape: str) -> str:
    cfg = get_config(arch)
    kind = SHAPES[shape]["kind"]
    if cfg.kind == "encoder" and kind == "prefill":
        return "prefill"            # encoder forward
    return kind


def cell_valid(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    s = SHAPES[shape]
    if cfg.kind == "encoder" and s["kind"] == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and cfg.kind in ("decoder", "encoder"):
        return False, "pure full-attention arch: needs sub-quadratic context"
    return True, ""


def valid_cells():
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, why = cell_valid(a, s)
            if ok:
                out.append((a, s))
    return out
