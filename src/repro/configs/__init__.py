"""repro.configs — assigned architectures (``--arch <id>``) + shape cells."""
from .registry import (ARCHS, SHAPES, get_config, get_smoke, valid_cells,
                       cell_step_kind)
