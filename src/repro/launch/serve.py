"""Production serving launcher: batched prefill + decode on the mesh.

    python -m repro.launch.serve --arch granite-3-2b --smoke \
        --batch 4 --prompt-len 32 --tokens 32

On real pods: drop --smoke; the plan switches to the serving layout
(TP-only bf16 params, sequence-sharded KV cache — §Perf cell C).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config, get_smoke
    from ..models import Model, init_params
    from ..serve import greedy_generate
    from .mesh import make_plan, make_production_mesh

    if args.smoke:
        cfg = get_smoke(args.arch)
        model = Model(cfg)
    else:
        cfg = get_config(args.arch).scaled(param_dtype="bfloat16")
        mesh = make_production_mesh()
        plan = make_plan(cfg, shape_kind="decode", batch=args.batch,
                         mesh=mesh)
        import dataclasses
        plan = dataclasses.replace(plan, fsdp_axes=())
        model = Model(cfg, plan)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    max_len = args.max_len or (args.prompt_len + args.tokens + 1)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, max_len, args.tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: {args.batch}×{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl compile)")
    print("first sequence:", np.asarray(out[0])[:24])


if __name__ == "__main__":
    main()
