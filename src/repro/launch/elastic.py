"""Elastic scaling + failure handling (DESIGN.md §8, 1000+-node design).

The mechanisms (all testable on CPU):
  1. mesh-independent checkpoints: restore onto ANY mesh/plan
     (``reshard_restore``; tested across mesh shapes in
     tests/test_train.py::TestCheckpoint and end-to-end in
     tests/elastic_scenario.py)
  2. deterministic data: batch(step) is pure — recovery replays exactly
  3. StepWatchdog: wall-time budget per step; ``is_straggling(elapsed)``
     returns True once a step exceeds ``grace`` multiples of the trailing
     median — callers decide the response (robust/recover.CheckpointedLoop
     warns; a real launcher would re-slice onto a hot spare or feed the
     preemption signal)

Operational story for real pods: the launcher (train.py) runs under a
process supervisor; on a node failure jax.distributed re-initializes with
the surviving hosts, make_production_mesh() builds the smaller mesh, and
reshard_restore() continues from the last step — only in-flight steps are
lost, and loss curves are bitwise-continuous thanks to (2).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import jax

from ..train.checkpoint import latest_step, restore_checkpoint


def reshard_restore(ckpt_dir: str, like, *, mesh=None, specs=None,
                    step: Optional[int] = None):
    """Restore a checkpoint onto a (possibly different) mesh/plan."""
    return restore_checkpoint(ckpt_dir, like, step=step, mesh=mesh,
                              specs=specs)


class StepWatchdog:
    """Detects straggling steps by trailing-median wall time."""

    def __init__(self, grace: float = 3.0, window: int = 20,
                 min_samples: int = 5):
        self.grace = grace
        self.times: deque = deque(maxlen=window)
        self.min_samples = min_samples
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def reset(self):
        """Forget trailing step times (back into warmup).

        Call on any topology or plan change — a regrid onto a smaller mesh
        or a re-planned exchange schedule changes per-step wall time, so a
        budget computed from the old configuration's trailing median would
        either flag every post-change step or mask a real straggler.
        ``robust/recover.CheckpointedLoop`` calls this after its
        ``on_topology``/``on_straggler`` hooks run.
        """
        self.times.clear()
        self._t0 = None

    def stop(self) -> float:
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        return dt

    def budget(self) -> Optional[float]:
        if len(self.times) < self.min_samples:
            return None
        med = sorted(self.times)[len(self.times) // 2]
        return med * self.grace

    def is_straggling(self, elapsed: float) -> bool:
        b = self.budget()
        return b is not None and elapsed > b
