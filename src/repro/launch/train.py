"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50                       # laptop smoke run
    python -m repro.launch.train --arch qwen2-72b --shape train_4k \
        --multi-pod                              # real pods (or dry-run env)

Wires the full substrate: production mesh + sharding plan, sharded params
/optimizer states, deterministic data pipeline with prefetch, gradient
accumulation, optional gradient compression on the pod axis, atomic
checkpoints with auto-resume, and the straggler watchdog.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on a 1x1 mesh (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    from ..configs import get_config, get_smoke
    from ..configs.registry import SHAPES
    from ..models import Model, init_params
    from ..models.model import init_param_specs
    from ..train import (AdamWConfig, SyntheticLM, init_opt_state,
                         latest_step, make_train_step, restore_checkpoint,
                         save_checkpoint)
    from ..train.data import Prefetcher
    from .elastic import StepWatchdog
    from .mesh import make_plan, make_production_mesh

    if args.smoke:
        cfg = get_smoke(args.arch).scaled(vocab=2048)
        mesh = None
        plan = None
        B, S = args.batch, args.seq
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        B, S = shape["batch"], shape["seq"]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = make_plan(cfg, multi_pod=args.multi_pod, shape_kind="train",
                         batch=B)

    model = Model(cfg, plan)
    params = init_params(cfg, seed=0)
    opt = init_opt_state(params)
    if mesh is not None:
        pspecs = init_param_specs(cfg, plan)
        to_sharded = lambda tree, specs: jax.tree.map(
            lambda x, s: jax.device_put(x, jax.NamedSharding(mesh, s)),
            tree, specs)
        params = to_sharded(params, pspecs)
        opt = dict(m=to_sharded(opt["m"], pspecs),
                   v=to_sharded(opt["v"], pspecs), step=opt["step"])

    compressor = None
    if args.compress == "int8":
        from ..dist.compression import int8_quantize
        compressor = int8_quantize
    # (topk needs state threading; exposed via dist.compression API)

    opt_cfg = AdamWConfig(warmup_steps=20, decay_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum=args.accum,
                                      compressor=compressor),
                      donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab, S, B, seed=11)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt), start = restore_checkpoint(args.ckpt_dir,
                                                  (params, opt))
        print(f"resumed from step {start}")
    pf = Prefetcher(data, start_step=start)
    wd = StepWatchdog()
    ctx = mesh if mesh is not None else _null()
    with ctx:
        for step in range(start, args.steps):
            wd.start()
            batch = jax.tree.map(jnp.asarray, pf.next())
            params, opt, metrics = step_fn(params, opt, batch)
            dt = wd.stop()
            if wd.is_straggling(dt):
                print(f"WARNING step {step}: straggler ({dt:.2f}s > "
                      f"{wd.budget():.2f}s budget) — launcher may re-slice")
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"({dt:.2f}s/step)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, (params, opt))
    pf.close()


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
