"""repro.launch — production mesh, dry-run, training/serving drivers."""
