import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# initialization. Placeholder host devices exist ONLY in this entrypoint —
# tests/benchmarks keep the real single device.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the production meshes and extract the
roofline terms (deliverable g).

    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out-dir results/]

Success = .lower().compile() completes for the 16×16 single-pod mesh and
the 2×16×16 multi-pod mesh; memory_analysis() proves per-device fit and
cost_analysis() + HLO collective walk feed EXPERIMENTS.md §Roofline.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None, depth: int | None = None,
               unroll: bool = False, model_opts: dict | None = None,
               accum: int = 1, serve_bf16: bool = False):
    """Returns (step_fn, args, in_shardings, meta) ready to lower.

    depth/unroll: shallow UNROLLED probe variants for exact cost analysis
    (see roofline.extrapolate_raw).
    """
    import dataclasses as _dc
    from ..configs.registry import SHAPES, get_config, cell_valid
    from ..launch.mesh import make_plan, make_production_mesh
    from ..models import Model, init_params
    from ..models.config import active_param_count
    from ..models.model import init_param_specs
    from ..train import AdamWConfig, make_train_step
    from ..train.data import make_batch_specs
    from ..serve.decode import make_serve_step

    ok, why = cell_valid(arch, shape_name)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {why}")
    cfg = get_config(arch)
    if depth is not None:
        cfg = _dc.replace(cfg, n_layers=depth)
    shape = SHAPES[shape_name]
    kind = shape["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(cfg, multi_pod=multi_pod, shape_kind=kind,
                     batch=shape["batch"], mesh=mesh)
    if overrides:
        plan = _dc.replace(plan, **overrides)
    if serve_bf16 and kind == "decode":
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    model = Model(cfg, plan, scan_unroll=unroll, **(model_opts or {}))
    params = init_params(cfg, abstract=True)
    pspecs = init_param_specs(cfg, plan)   # validates every spec against
    # the plan's axes/shapes — a bad plan fails loudly before lowering
    B, S = shape["batch"], shape["seq"]
    dp_total = plan.dp_size
    batch_shardable = B % dp_total == 0

    def batch_sharding(spec_tree):
        def leaf(s):
            nd = len(s.shape)
            if not batch_shardable:
                return NamedSharding(mesh, P(*(None,) * nd))
            if nd >= 1 and s.shape[0] == B:
                return NamedSharding(mesh, P(*(plan.dp(),) +
                                             (None,) * (nd - 1)))
            if nd >= 2 and s.shape[1] == B:      # pos3 (3, B, S)
                return NamedSharding(mesh, P(None, plan.dp(),
                                             *(None,) * (nd - 2)))
            return NamedSharding(mesh, P(*(None,) * nd))
        return jax.tree.map(leaf, spec_tree)

    ns = lambda tree: jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree)
    tokens_per_step = B * S if kind == "train" else \
        (B * S if kind == "prefill" else B)
    n_active = active_param_count(cfg)
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens_per_step
    meta = dict(arch=arch, shape=shape_name, kind=kind, multi_pod=multi_pod,
                batch=B, seq=S, n_devices=mesh.size,
                active_params=n_active, model_flops=model_flops)

    if kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(model, opt_cfg, accum=accum)
        opt_specs = dict(m=pspecs, v=pspecs, step=P())
        opt_abstract = dict(
            m=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                          jnp.float32),
                           params),
            v=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                          jnp.float32),
                           params),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        batch_specs = make_batch_specs(cfg, shape, plan)
        in_sh = (ns(pspecs), ns(opt_specs), batch_sharding(batch_specs))
        fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1))
        args = (params, opt_abstract, batch_specs)
        return fn, args, meta, mesh

    if kind == "prefill":
        def prefill_fn(p, batch):
            logits, aux, _ = model.forward(p, batch, remat=False)
            return logits[:, -1, :]
        batch_specs = make_batch_specs(cfg, shape, plan)
        if cfg.kind != "encoder":
            batch_specs.pop("labels", None)
        else:
            batch_specs.pop("targets", None)
        in_sh = (ns(pspecs), batch_sharding(batch_specs))
        fn = jax.jit(prefill_fn, in_shardings=in_sh)
        return fn, (params, batch_specs), meta, mesh

    # decode
    serve = make_serve_step(model)
    caches = model.init_cache(B, S, abstract=True)
    cache_specs = _cache_specs(model, plan, caches)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    off = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = NamedSharding(mesh, P(plan.dp(), None) if batch_shardable
                           else P(None, None))
    off_sh = NamedSharding(mesh, P(plan.dp()) if batch_shardable
                           else P(None))
    in_sh = (ns(pspecs), ns(cache_specs), tok_sh, off_sh)
    fn = jax.jit(serve, in_shardings=in_sh, donate_argnums=(1,))
    return fn, (params, caches, tok, off), meta, mesh


def _cache_specs(model, plan, caches):
    cfg = model.cfg
    specs = {}
    for pos, c in caches.items():
        if "k" in c:            # GQA kv cache (reps, B, S, KVH, hd)
            kv = plan.cache_spec("kv", dict(kvh=cfg.n_kv_heads, hd=cfg.hd))
            specs[pos] = dict(k=P(None, *kv), v=P(None, *kv),
                              offset=P(None))
        elif "c_kv" in c:       # MLA latent cache
            lat = plan.cache_spec("kv_flat", dict(x=cfg.kv_lora_rank))
            rope = plan.cache_spec("kv", dict(kvh=1, hd=cfg.qk_rope_dim))
            specs[pos] = dict(c_kv=P(None, *lat), k_rope=P(None, *rope),
                              offset=P(None))
        else:                   # SSM state
            st = plan.cache_spec("ssm", dict(h=cfg.ssm_heads))
            cv = plan.cache_spec("conv",
                                 dict(c=cfg.d_inner + 2 * cfg.ssm_state))
            specs[pos] = dict(conv=P(None, *cv), state=P(None, *st))
    return specs


def run_cell(arch, shape_name, *, multi_pod, out_dir=None, overrides=None,
             verbose=True, probes=True, model_opts=None, accum=1,
             serve_bf16=False, tag_extra=""):
    from .roofline import (extrapolate_raw, raw_metrics, roofline_terms,
                           terms_from_raw)
    from ..configs.registry import get_config
    from ..models.model import period_of
    kw = dict(model_opts=model_opts, accum=accum, serve_bf16=serve_bf16)
    t0 = time.time()
    fn, args, meta, mesh = build_cell(arch, shape_name, multi_pod=multi_pod,
                                      overrides=overrides, **kw)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    print(compiled.memory_analysis())      # proves it fits
    from .roofline import cost_dict
    ca = cost_dict(compiled)               # FLOPs/bytes for §Roofline
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    if probes:
        # XLA counts scan bodies once — lower 2 shallow UNROLLED probes and
        # extrapolate linearly in depth (exact; see roofline.py)
        cfg_full = get_config(arch)
        period = period_of(cfg_full)
        reps = cfg_full.n_layers // period
        raws = []
        for d in (period, 2 * period):
            pf, pargs, _, pmesh = build_cell(
                arch, shape_name, multi_pod=multi_pod, overrides=overrides,
                depth=d, unroll=True, **kw)
            with pmesh:
                pcomp = pf.lower(*pargs).compile()
            raws.append(raw_metrics(pcomp))
        raw = extrapolate_raw(raws[0], raws[1], reps)
        rf = terms_from_raw(raw, n_devices=meta["n_devices"],
                            model_flops=meta["model_flops"],
                            memory_stats=compiled.memory_analysis())
        rf["scanned_raw"] = raw_metrics(compiled)
        rf["probe_raws"] = raws
    else:
        rf = roofline_terms(compiled, n_devices=meta["n_devices"],
                            model_flops=meta["model_flops"])
    result = dict(meta=meta, lower_s=t_lower, compile_s=t_compile, **rf)
    if verbose:
        ts = rf["terms_seconds"]
        print(f"[{arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}] "
              f"compute={ts['compute']:.4f}s memory={ts['memory']:.4f}s "
              f"collective={ts['collective']:.4f}s "
              f"dominant={rf['dominant']} "
              f"roofline_frac={rf['roofline_fraction']} "
              f"compile={t_compile:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        if overrides:
            tag += "_" + "_".join(f"{k}={v}" for k, v in overrides.items())
        if tag_extra:
            tag += "_" + tag_extra
        with open(os.path.join(out_dir, f"dryrun_{tag}.json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--cast-early", action="store_true",
                    help="bf16 param cast before the sharded-use boundary")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over the data axes (serving plan)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="store params in bf16 for decode cells")
    ap.add_argument("--tag", default="", help="extra tag for the result file")
    ap.add_argument("--moe-ep", action="store_true",
                    help="shard_map expert-parallel MoE dispatch")
    args = ap.parse_args()
    from ..configs.registry import valid_cells
    overrides = {}
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.no_fsdp:
        overrides["fsdp_axes"] = ()
    if args.moe_ep:
        overrides["moe_ep"] = True
    overrides = overrides or None
    model_opts = dict(cast_early=True) if args.cast_early else None
    cells = valid_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                # probes (exact roofline) on the single-pod mesh — the
                # §Roofline table is single-pod; multi-pod proves the 'pod'
                # axis shards (compile success + scanned collective pattern)
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out_dir,
                         overrides=overrides, probes=not mp,
                         model_opts=model_opts, accum=args.accum,
                         serve_bf16=args.serve_bf16, tag_extra=args.tag)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"dry-run OK: {len(cells) * len(meshes)} cells")


if __name__ == "__main__":
    main()
