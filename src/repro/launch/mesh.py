"""Production meshes — CombBLAS grids for the LM stack (DESIGN.md §5).

  single-pod: (data=16, model=16)        = the paper's √p×√p 2D grid
  multi-pod : (pod=2, data=16, model=16) = the paper's c×√(p/c)×√(p/c) 3D
              CA grid; 'pod' is the layer axis (hierarchical collectives).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py does this for you)")
    from ..core import compat
    return compat.make_mesh(shape, axes, devices=devices[:ndev])


def make_plan(cfg, *, multi_pod: bool = False, shape_kind: str = "train",
              batch: int = 0, seq_parallel: bool = False, mesh=None,
              moe_ep: bool = False):
    """ShardingPlan matched to (mesh, arch, shape)."""
    from ..dist.shardings import ShardingPlan
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp_size = 32 if multi_pod else 16
    context_parallel = shape_kind == "decode" and batch < dp_size
    return ShardingPlan(
        dp_axes=dp_axes, model_axis="model", model_size=16,
        fsdp_axes=("data",),          # params sharded within a pod; the pod
        # axis is pure DP with hierarchical grad reduction (see DESIGN §5)
        seq_parallel=seq_parallel,
        context_parallel=context_parallel,
        dp_size=dp_size, moe_ep=moe_ep, mesh=mesh)
