"""Production meshes — CombBLAS grids for the LM stack (DESIGN.md §5).

  single-pod: (data=16, model=16)        = the paper's √p×√p 2D grid
  multi-pod : (pod=2, data=16, model=16) = the paper's c×√(p/c)×√(p/c) 3D
              CA grid; 'pod' is the layer axis (hierarchical collectives).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax

PRODUCTION_SHAPES = {
    False: ((16, 16), ("data", "model")),
    True: ((2, 16, 16), ("pod", "data", "model")),
}

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = PRODUCTION_SHAPES[bool(multi_pod)]
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py does this for you)")
    from ..core import compat
    return compat.make_mesh(shape, axes, devices=devices[:ndev])


def make_plan(cfg, *, multi_pod: bool = False, shape_kind: str = "train",
              batch: int = 0, seq_parallel: bool = False, mesh=None,
              moe_ep: bool = False):
    """ShardingPlan matched to (mesh, arch, shape).

    Axis layout comes from the mesh when one is given (so tests and
    smaller dry-runs get a consistent plan on ANY (…, data, model) mesh);
    without a mesh it falls back to the production shapes above.  All
    non-model axes are data parallel; only the innermost ('data') axis
    shards parameters — the pod axis is pure DP with hierarchical grad
    reduction (DESIGN §5).
    """
    from ..dist.shardings import ShardingPlan
    if mesh is not None:
        axes = tuple(mesh.axis_names)
        sizes = dict(mesh.shape)
    else:
        shape, axes = PRODUCTION_SHAPES[bool(multi_pod)]
        sizes = dict(zip(axes, shape))
    if MODEL_AXIS not in sizes or len(axes) < 2:
        raise ValueError(
            f"plan needs a mesh with a {MODEL_AXIS!r} axis and ≥1 data "
            f"axis, got {dict(sizes)}")
    dp_axes = tuple(a for a in axes if a != MODEL_AXIS)
    dp_size = 1
    for a in dp_axes:
        dp_size *= sizes[a]
    model_size = sizes[MODEL_AXIS]
    context_parallel = shape_kind == "decode" and batch < dp_size
    return ShardingPlan(
        dp_axes=dp_axes, model_axis=MODEL_AXIS, model_size=model_size,
        fsdp_axes=(dp_axes[-1],),     # params sharded within a pod; outer
        # dp axes (pod) are pure DP with hierarchical grad reduction
        seq_parallel=seq_parallel,
        context_parallel=context_parallel,
        dp_size=dp_size, moe_ep=moe_ep, mesh=mesh, axis_sizes=sizes)
