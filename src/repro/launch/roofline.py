"""Roofline-term extraction from compiled SPMD executables (DESIGN.md §7).

The compiled module is per-device (post-GSPMD partitioning), so
``cost_analysis()`` FLOPs/bytes are PER-DEVICE numbers. Collective bytes
come from walking the compiled HLO text and converting each collective's
result shape into wire bytes per device:

  all-gather        recv = result × (g-1)/g
  reduce-scatter    send = result × (g-1)          (input = result × g)
  all-reduce        2 × result × (g-1)/g           (ring reduce+broadcast)
  all-to-all        result × (g-1)/g
  collective-permute result                        (one neighbor hop)

with g = participants (parsed from replica_groups). The collective term
divides by ONE ICI link (50 GB/s): a deliberately conservative single-link
serialization model — multi-link overlap is credited in §Perf only when
the schedule provably uses disjoint axes. TPU v5e constants:
197 TFLOP/s bf16, 819 GB/s HBM.
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind + op counts."""
    out = {k: 0.0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                      # count the -start only
        result_type, kind = m.group(1), m.group(2)
        size = _shape_bytes(result_type)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([t for t in gm.group(1).split(",") if t])
        else:
            gm2 = _GROUPS2_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        g = max(g, 2)
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)
        elif kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:                             # collective-permute
            wire = size
        out[kind] += wire
        counts[kind] += 1
    return dict(bytes=out, counts=counts,
                total=float(sum(out.values())))


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across jax versions: older releases return
    a one-element list of dicts, newer ones the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def raw_metrics(compiled) -> dict:
    """Per-device flops/bytes/collective-wire-bytes of one executable."""
    ca = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                coll=coll)


def extrapolate_raw(r1: dict, r2: dict, reps: int) -> dict:
    """Linear depth extrapolation: total = probe1 + (probe2-probe1)·(reps-1).

    XLA's cost analysis counts while-loop (lax.scan) bodies ONCE, so a
    scanned L-layer model reports ~1-layer flops. We therefore lower two
    UNROLLED shallow probes (depth = 1 and 2 periods); their difference is
    the exact per-period cost (fwd+bwd+remat+optimizer slice), and
    everything outside the stack (embedding, logits, loss) is the probe-1
    intercept. Exact for costs linear in depth — which all stacked-layer
    costs are.
    """
    out = dict(flops=r1["flops"] + (r2["flops"] - r1["flops"]) * (reps - 1),
               bytes=r1["bytes"] + (r2["bytes"] - r1["bytes"]) * (reps - 1))
    coll_b = {}
    for k in r1["coll"]["bytes"]:
        b1, b2 = r1["coll"]["bytes"][k], r2["coll"]["bytes"][k]
        coll_b[k] = b1 + (b2 - b1) * (reps - 1)
    counts = {}
    for k in r1["coll"]["counts"]:
        c1, c2 = r1["coll"]["counts"][k], r2["coll"]["counts"][k]
        counts[k] = int(c1 + (c2 - c1) * (reps - 1))
    out["coll"] = dict(bytes=coll_b, counts=counts,
                       total=float(sum(coll_b.values())))
    return out


def terms_from_raw(raw: dict, *, n_devices: int, model_flops: float,
                   memory_stats=None) -> dict:
    flops, bytes_accessed = raw["flops"], raw["bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = raw["coll"]["total"] / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bound = max(t_compute, t_memory, t_coll)
    result = dict(
        per_device_flops=flops,
        per_device_bytes=bytes_accessed,
        collective=raw["coll"],
        terms_seconds=terms,
        dominant=max(terms, key=terms.get),
        step_time_lower_bound_s=bound,
        model_flops_global=model_flops,
        hlo_flops_global=flops * n_devices,
        useful_flops_ratio=(model_flops / (flops * n_devices))
        if flops and model_flops else None,
        roofline_fraction=(model_flops / n_devices / PEAK_FLOPS) / bound
        if bound and model_flops else None)
    if memory_stats is not None:
        ma = memory_stats
        result["memory_per_device"] = dict(
            args=ma.argument_size_in_bytes, out=ma.output_size_in_bytes,
            temp=ma.temp_size_in_bytes, alias=ma.alias_size_in_bytes,
            total_transient=ma.argument_size_in_bytes +
            ma.output_size_in_bytes + ma.temp_size_in_bytes -
            ma.alias_size_in_bytes)
    return result


def roofline_terms(compiled, *, n_devices: int, model_flops: float = 0.0):
    """Compute the three roofline terms from a compiled executable."""
    ca = cost_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll["total"] / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    ma = compiled.memory_analysis()
    result = dict(
        per_device_flops=flops,
        per_device_bytes=bytes_accessed,
        collective=coll,
        terms_seconds=terms,
        dominant=dominant,
        step_time_lower_bound_s=bound,
        model_flops_global=model_flops,
        hlo_flops_global=flops * n_devices,
        useful_flops_ratio=(model_flops / (flops * n_devices))
        if flops and model_flops else None,
        roofline_fraction=(model_flops / n_devices / PEAK_FLOPS) / bound
        if bound and model_flops else None,
        memory_per_device=dict(
            args=ma.argument_size_in_bytes,
            out=ma.output_size_in_bytes,
            temp=ma.temp_size_in_bytes,
            alias=ma.alias_size_in_bytes,
            total_transient=ma.argument_size_in_bytes +
            ma.output_size_in_bytes + ma.temp_size_in_bytes -
            ma.alias_size_in_bytes),
    )
    return result
