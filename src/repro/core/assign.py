"""Distributed vector assign / extract (paper §3.3, VecAssign in Table 1).

Irregular vector updates are the latency-bound tail of graph algorithms
(Awerbuch-Shiloach / FastSV). CombBLAS 2.0's schemes, adapted to SPMD:

 - **Two-stage hierarchical all-to-all**: entries are routed first along the
   'row' axis (to the destination process row), then along 'col'. Each stage
   is an all-to-all on a √p-sized communicator — the paper's "collective
   communication on reduced communicators", which is also exactly how the
   multi-pod LM stack's hierarchical collectives work (DESIGN.md §5).
 - **Skew-aware path** (``skew_aware=True``): per-destination counts are
   summed grid-wide; destinations above ``heavy_frac`` of total traffic are
   served via an all-gather (broadcast-like: every device sees heavy
   entries, owners filter), while the light remainder rides the bounded
   all-to-all — the paper's 90%-heavy-process separation, expressed in SPMD.

All updates use GLOBAL int32 indices (the vector length must fit 32 bits on
device; CombBLAS's 64-bit global indices are a host-side concern in this
port — see DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map
from .coo import SENTINEL
from .dist import DistVec, specs_of
from .semiring import Monoid, segment_reduce

Array = jax.Array


def _bucketize(dest: Array, payloads: tuple[Array, ...], nb: int, cap_b: int,
               fills):
    """Radix-place entries into nb buckets of cap_b slots each.

    dest >= nb marks invalid entries. Returns (bucketed_payloads, ok).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    d_s = dest[order]
    seg = jnp.searchsorted(d_s, jnp.arange(nb + 1)).astype(jnp.int32)
    counts = seg[1:] - seg[:-1]
    ok = jnp.all(counts <= cap_b)
    within = jnp.arange(n, dtype=jnp.int32) - seg[jnp.clip(d_s, 0, nb - 1)]
    keep = (d_s < nb) & (within < cap_b)
    slot = jnp.where(keep, d_s * cap_b + jnp.minimum(within, cap_b - 1),
                     nb * cap_b)  # OOB -> dropped
    outs = []
    for p, fill in zip(payloads, fills):
        buf = jnp.full((nb * cap_b,) + p.shape[1:], fill, p.dtype)
        outs.append(buf.at[slot].set(p[order], mode="drop"))
    return tuple(outs), ok


def _a2a(x: Array, axis: str, nb: int) -> Array:
    return jax.lax.all_to_all(x.reshape((nb, -1) + x.shape[1:]), axis, 0, 0) \
        .reshape((x.shape[0],) + x.shape[1:])


def route_to_pieces(gidx: Array, payloads: tuple[Array, ...], fills,
                    *, n: int, grid, cap: int):
    """Route (global_idx, payload) entries to the owning piece (layout 'col').

    Call inside shard_map. Returns (local_idx, payloads, ok): entries now on
    their owner device, indices localized to the piece, SENTINEL padding.
    """
    pr, pc = grid
    vb = -(-n // (pr * pc))
    valid = gidx != SENTINEL
    piece = jnp.where(valid, gidx // vb, pr * pc)
    dest_i = jnp.where(valid, piece % pr, pr)            # layout 'col'
    dest_j = jnp.where(valid, piece // pr, pc)
    # stage 1: along 'row' to the destination process row
    (g1, dj1, *p1), ok1 = _bucketize(
        dest_i, (gidx, dest_j) + tuple(payloads), pr, cap // pr,
        (SENTINEL, pc) + tuple(fills))
    g1 = _a2a(g1, "row", pr)
    dj1 = _a2a(dj1, "row", pr)
    p1 = [_a2a(x, "row", pr) for x in p1]
    # stage 2: along 'col' to the destination process column
    valid1 = g1 != SENTINEL
    dj1 = jnp.where(valid1, dj1, pc)
    (g2, *p2), ok2 = _bucketize(dj1, (g1,) + tuple(p1), pc, cap // pc,
                                (SENTINEL,) + tuple(fills))
    g2 = _a2a(g2, "col", pc)
    p2 = [_a2a(x, "col", pc) for x in p2]
    lidx = jnp.where(g2 != SENTINEL, g2 % vb, SENTINEL)
    return lidx, tuple(p2), ok1 & ok2


def assign(v: DistVec, gidx: Array, val: Array, *, mesh: Mesh,
           route_cap: int | None = None, add: Monoid | None = None,
           accumulate: bool = False, skew_aware: bool = False,
           heavy_frac: float = 0.5):
    """v[gidx] = val (distributed scatter). Returns (DistVec, ok).

    gidx/val: (pr, pc, cap) per-device update lists, global indices,
    SENTINEL-padded. ``add`` merges duplicate updates (None = overwrite;
    duplicate targets then take an arbitrary writer, as in CombBLAS's
    non-deterministic assign). ``accumulate=True`` additionally combines
    the merged update with the existing value (v[i] = add(v[i], upd)).
    """
    assert v.layout == "col"
    pr, pc = v.grid
    cap = gidx.shape[-1]
    route_cap = route_cap or max(cap * 2, 64)
    route_cap = -(-route_cap // (pr * pc)) * pr * pc   # divisible by pr, pc
    vb = v.vb
    n = v.n

    def body(data, gi, gv):
        gi = gi.reshape(-1)
        gv = gv.reshape((-1,) + gv.shape[3:])
        mine_extra = None
        if skew_aware:
            # grid-wide per-piece traffic census (cheap: p counts/device)
            piece = jnp.where(gi != SENTINEL, gi // vb, pr * pc)
            counts = jax.ops.segment_sum(jnp.ones_like(piece), piece,
                                         pr * pc + 1)[:pr * pc]
            total = jax.lax.psum(counts, ("row", "col"))
            heavy = total.astype(jnp.float32) > \
                heavy_frac * jnp.maximum(jnp.sum(total), 1).astype(jnp.float32)
            is_heavy = heavy[jnp.clip(piece, 0, pr * pc - 1)] & \
                (gi != SENTINEL)
            # heavy entries: broadcast to all, owners filter
            hg = jnp.where(is_heavy, gi, SENTINEL)
            hv = gv
            hg_all = jax.lax.all_gather(hg, ("row", "col"), tiled=True)
            hv_all = jax.lax.all_gather(hv, ("row", "col"), tiled=True)
            i = jax.lax.axis_index("row")
            j = jax.lax.axis_index("col")
            my_piece = j * pr + i
            mine = (hg_all != SENTINEL) & (hg_all // vb == my_piece)
            mine_extra = (jnp.where(mine, hg_all % vb, SENTINEL), hv_all)
            gi = jnp.where(is_heavy, SENTINEL, gi)       # light path only
        lidx, (lval,), ok = route_to_pieces(
            gi, (gv,), (jnp.asarray(0, gv.dtype),),
            n=n, grid=(pr, pc), cap=route_cap)
        d = data.reshape((-1,) + data.shape[3:])
        if mine_extra is not None:
            lidx = jnp.concatenate([lidx, mine_extra[0]])
            lval = jnp.concatenate([lval, mine_extra[1]])
        if add is None:
            d = d.at[jnp.where(lidx != SENTINEL, lidx, d.shape[0])] \
                .set(lval, mode="drop")
        else:
            # duplicates merged under the monoid, then REPLACE (CombBLAS
            # assign semantics) or accumulate into the existing value
            ids = jnp.where(lidx != SENTINEL, lidx, d.shape[0])
            upd = segment_reduce(lval, ids, d.shape[0], add)
            touched = jax.ops.segment_sum(
                jnp.ones_like(ids), ids, d.shape[0] + 1)[:d.shape[0]] > 0
            d = jnp.where(touched, add.op(d, upd) if accumulate else upd, d)
        return d[None, None], ok[None, None]

    out, ok = shard_map(
        body, mesh=mesh,
        in_specs=(P("row", "col", None), P("row", "col", None),
                  P("row", "col", None)),
        out_specs=(P("row", "col", None), P("row", "col")))(v.data, gidx, val)
    return DistVec(out, v.n, v.grid, v.layout), ok


def extract(v: DistVec, gidx: Array, *, mesh: Mesh,
            route_cap: int | None = None):
    """w[s] = v[gidx[s]] (distributed gather). Returns (vals, ok).

    gidx: (pr, pc, cap) request lists (global indices, SENTINEL padding);
    result vals aligned with gidx slots. Requests are routed to owners with
    provenance (src rank + slot), answered, and routed back — 4 all-to-alls
    on √p communicators.
    """
    assert v.layout == "col"
    pr, pc = v.grid
    cap = gidx.shape[-1]
    route_cap = route_cap or max(cap * 2, 64)
    route_cap = -(-route_cap // (pr * pc)) * pr * pc   # divisible by pr, pc
    n, vb = v.n, v.vb

    def body(data, gi):
        gi = gi.reshape(-1)
        d = data.reshape(-1)
        i = jax.lax.axis_index("row")
        j = jax.lax.axis_index("col")
        src = (i * pc + j).astype(jnp.int32)
        slots = jnp.arange(cap, dtype=jnp.int32)
        lidx, (src_r, slot_r), ok1 = route_to_pieces(
            gi, (jnp.full((cap,), src), slots),
            (jnp.int32(pr * pc), jnp.int32(cap)),
            n=n, grid=(pr, pc), cap=route_cap)
        ans = d[jnp.clip(lidx, 0, vb - 1)]
        # route answers back: destination = src rank (row-major i*pc+j)
        valid = lidx != SENTINEL
        back_i = jnp.where(valid, src_r // pc, pr)
        back_j = jnp.where(valid, src_r % pc, pc)
        (s1, bj1, a1), okb1 = _bucketize(
            back_i, (slot_r, back_j, ans), pr, route_cap // pr,
            (jnp.int32(cap), jnp.int32(pc), jnp.asarray(0, ans.dtype)))
        s1 = _a2a(s1, "row", pr)
        bj1 = _a2a(bj1, "row", pr)
        a1 = _a2a(a1, "row", pr)
        bj1 = jnp.where(s1 != cap, bj1, pc)
        (s2, a2), okb2 = _bucketize(bj1, (s1, a1), pc, route_cap // pc,
                                    (jnp.int32(cap), jnp.asarray(0, ans.dtype)))
        s2 = _a2a(s2, "col", pc)
        a2 = _a2a(a2, "col", pc)
        out = jnp.zeros((cap,), ans.dtype)
        out = out.at[jnp.where(s2 != cap, s2, cap)].set(a2, mode="drop")
        return out[None, None], (ok1 & okb1 & okb2)[None, None]

    vals, ok = shard_map(
        body, mesh=mesh,
        in_specs=(P("row", "col", None), P("row", "col", None)),
        out_specs=(P("row", "col", None), P("row", "col")))(v.data, gidx)
    return vals, ok
