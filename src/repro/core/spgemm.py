"""Distributed SpGEMM: 2D SUMMA (rotation + all-gather) and 3D CA (paper §3.2).

2D (paper's Sparse SUMMA, hardware-adapted — DESIGN.md §4.1):
  - variant='rotation' (default): Cannon-style systolic schedule. One
    multi-axis collective-permute performs the initial skew, then q stages of
    neighbor rotation (A left along 'col', B up along 'row') each followed by
    a local O(flops) expansion. Communication volume per device equals the
    paper's Table 1 bandwidth term O(nnz(A+B)/√p); the primitive is the
    torus-native permute instead of an MPI broadcast.
  - variant='allgather': the literal broadcast formulation — each device
    all-gathers its process row of A and process column of B, then runs the
    q local multiplies. Same volume, √q-deeper buffers (the memory/latency
    tradeoff the paper describes for 2D SUMMA at scale).

3D CA (paper Fig 2): inputs on a (L, q, q) grid, A column-sliced and B
row-sliced across layers. Each layer runs an independent 2D multiply over a
contraction dim shrunk by L (broadcast/rotation volume shrinks by the
paper's √c factor on the smaller communicator), then one inter-layer
all-to-all scatters partial C column sub-blocks and a local semiring merge
forms C distributed like A.

Merging (paper §5 "binary merge scheme", DESIGN.md §4.4): every stage
product buffer is compacted (per-stage packed-key dedup to
min(prod_cap, out_cap) slots) and then combined through the merge engine:

  merge='deferred'    pairwise merge tree over the q compacted stage
                      buffers — O(n) rank-placement merges, never a sort of
                      the q·prod_cap concatenation (and never of its
                      padding slack).
  merge='incremental' O(n) merge_sorted of each compacted stage into the
                      row-sorted accumulator (less memory, more steps).
  merge='sort'        the seed behavior — concatenate all q padded stage
                      buffers and dedup once. Kept for tiny problems (the
                      planner picks it when q·prod_cap is small) and as the
                      benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..robust import audit as _audit
from .compat import pvary, shard_map
from .coo import COO, SENTINEL
from .dist import DistSpMat, DistSpMat3D, specs_of
from .local_spgemm import _expand
from .mask import LocalMask, MaskSpec, apply_val_pred, filter_products
from .merge import (key_dtype, kv_empty, kv_from_products, kv_merge2,
                    kv_to_coo, kv_tree, merge_stage_products, pack_keys)
from .semiring import ARITHMETIC, Semiring

Array = jax.Array


def _cannon_perms(q, skew_a=True):
    """(src, dst) pairs on a row-major q×q grid for the initial skew."""
    if skew_a:  # A(i, j) -> A(i, (j - i) mod q)
        return [(r * q + c, r * q + (c - r) % q)
                for r in range(q) for c in range(q)]
    # B(i, j) -> B((i - j) mod q, j)
    return [(r * q + c, ((r - c) % q) * q + c)
            for r in range(q) for c in range(q)]


def _shift_perm(q, axis_len, left=True):
    return [(s, (s - 1) % axis_len) if left else (s, (s + 1) % axis_len)
            for s in range(axis_len)]


def _tile_permute(tile: COO, axes, perm) -> COO:
    r = jax.lax.ppermute(tile.row, axes, perm)
    c = jax.lax.ppermute(tile.col, axes, perm)
    v = jax.lax.ppermute(tile.val, axes, perm)
    n = jax.lax.ppermute(tile.nnz, axes, perm)
    # whole tiles move between devices; each one keeps its internal order
    return COO(r, c, v, n, tile.shape, tile.order)


def _merge_products(rows, cols, vals, nvalid, shape, sr, out_cap,
                    order="row", val_pred=None):
    prods = COO(rows, cols, vals,
                jnp.minimum(nvalid, rows.shape[0]).astype(jnp.int32),
                shape, "none")
    d = prods.dedup(sr.add, order=order)
    d = apply_val_pred(d, val_pred, sr.add.identity)
    # overflow must be read from the PRE-clamp nnz: with_cap() truncates
    # nnz to out_cap, which would make this check vacuously true
    ok = d.nnz <= out_cap
    return d.with_cap(out_cap, sr.add.identity), ok


def _local_spgemm_2d(a_tile: COO, b_tile: COO, sr, q, prod_cap, out_cap,
                     variant, merge, mask: LocalMask | None = None,
                     val_pred=None):
    """Body run per device under shard_map for the 2D algorithm.

    The engine paths ('deferred'/'incremental') run at the kv level:
    per-stage compaction to stage_cap = min(prod_cap, out_cap) — sound
    because a stage's distinct count is bounded by the final nnz(C), and
    checked pre-clamp by the ok flags — then rank-placement merging of the
    compacted streams, decoding rows/cols exactly once.

    ``mask`` prunes every stage's expanded products against the local mask
    tile BEFORE any merge stage (§4.7): a masked stage's distinct count is
    bounded by the masked nnz(C), so mask-sized out/stage caps stay sound
    (still guarded pre-clamp by the ok flags). ``val_pred`` drops merged
    entries by output value in the final compaction.
    """
    shape = (a_tile.shape[0], b_tile.shape[1])
    stage_cap = min(prod_cap, out_cap)
    ident = sr.add.identity
    if key_dtype(shape) is None:
        merge = "sort"        # unpackable tile: the engine needs x64 keys

    if variant == "allgather":
        # gather my process row of A and process column of B (the broadcast
        # formulation; all stages' operands live simultaneously)
        ar = jax.tree.map(lambda x: jax.lax.all_gather(x, "col"), a_tile)
        bc = jax.tree.map(lambda x: jax.lax.all_gather(x, "row"), b_tile)

        def stage(s):
            at = COO(ar.row[s], ar.col[s], ar.val[s], ar.nnz[s],
                     a_tile.shape, a_tile.order)
            bt = COO(bc.row[s], bc.col[s], bc.val[s], bc.nnz[s],
                     b_tile.shape, b_tile.order)
            return _expand(at, bt, sr, prod_cap)

        outs = [stage(s) for s in range(q)]
        ok = jnp.all(jnp.stack([o[4] for o in outs]))
        if merge == "sort":
            # seed path: concatenate q full padded buffers, sort once —
            # masked products are dropped per stage, before the concat
            if mask is not None:
                outs = [(*filter_products(r, c_, v, shape, mask, ident),
                         n, o) for (r, c_, v, n, o) in outs]
            rows = jnp.concatenate([o[0] for o in outs])
            cols = jnp.concatenate([o[1] for o in outs])
            vals = jnp.concatenate([o[2] for o in outs])
            total = sum(o[3] for o in outs)
            c, ok2 = _merge_products(rows, cols, vals, total, shape, sr,
                                     out_cap, val_pred=val_pred)
            return c, ok & ok2
        # merge engine: mask-filter + compact each stage, then fold the q
        # sorted streams
        c, okm = merge_stage_products(
            [(r, c_, v, jnp.minimum(n, prod_cap)) for (r, c_, v, n, _)
             in outs],
            shape, sr.add, stage_cap, out_cap, mask=mask)
        return apply_val_pred(c, val_pred, ident), ok & okm

    # rotation (Cannon)
    axes = ("row", "col")
    a_skew = _tile_permute(a_tile, axes, _cannon_perms(q, skew_a=True))
    b_skew = _tile_permute(b_tile, axes, _cannon_perms(q, skew_a=False))
    if mask is not None:
        # loop-invariant closure of the scan bodies below: mark varying so
        # newer-jax manual-axes checks accept the device-local mask arrays
        mask = LocalMask(pvary(mask.keys, axes),
                         None if mask.allow is None
                         else pvary(mask.allow, axes),
                         mask.complement, mask.order)

    if merge == "incremental":
        kacc, vacc, nacc = kv_empty(shape, out_cap,
                                    vals_dtype(sr, a_tile, b_tile), sr.add)
        # constants entering a shard_map scan carry must be marked varying
        # (newer jax; identity on 0.4.x — see compat.pvary)
        kacc, vacc, nacc = (pvary(kacc, ("row", "col")),
                            pvary(vacc, ("row", "col")),
                            pvary(nacc, ("row", "col")))

        def body(carry, _):
            at, bt, kacc, vacc, nacc, ok = carry
            r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
            # mask-filter + compact the stage, then O(n) rank-placement
            # merge into the sorted kv accumulator — never re-sorted
            ks, vs, ns, okc = kv_from_products(
                r, c, v, jnp.minimum(n, prod_cap), shape, sr.add, stage_cap,
                mask=mask)
            kacc, vacc, nacc, okm = kv_merge2(kacc, vacc, nacc, ks, vs, ns,
                                              sr.add, out_cap)
            ok = ok & okx & okc & okm
            at = _tile_permute(at, "col", _shift_perm(q, q, left=True))
            bt = _tile_permute(bt, "row", _shift_perm(q, q, left=True))
            return (at, bt, kacc, vacc, nacc, ok), None

        ok0 = pvary(jnp.bool_(True), ("row", "col"))
        (at, bt, kacc, vacc, nacc, ok), _ = jax.lax.scan(
            body, (a_skew, b_skew, kacc, vacc, nacc, ok0), None, length=q)
        c = kv_to_coo(kacc, vacc, nacc, shape, sr.add, out_cap)
        return apply_val_pred(c, val_pred, ident), ok

    if merge == "sort":
        # seed path: collect q padded product buffers, concat, sort once
        def body(carry, _):
            at, bt = carry
            r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
            if mask is not None:
                r, c, v = filter_products(r, c, v, shape, mask, ident)
            at = _tile_permute(at, "col", _shift_perm(q, q, left=True))
            bt = _tile_permute(bt, "row", _shift_perm(q, q, left=True))
            return (at, bt), (r, c, v, jnp.minimum(n, prod_cap), okx)

        (_, _), (rs, cs, vs, ns, oks) = jax.lax.scan(
            body, (a_skew, b_skew), None, length=q)
        rows = rs.reshape(-1)
        cols = cs.reshape(-1)
        vals = vs.reshape((-1,) + vs.shape[2:])
        c, ok2 = _merge_products(rows, cols, vals, rows.shape[0], shape, sr,
                                 out_cap, val_pred=val_pred)
        return c, jnp.all(oks) & ok2

    # deferred (merge tree): mask-filter + compact each stage inside the
    # scan, then fold the q sorted kv streams pairwise — no concat-and-sort
    def body(carry, _):
        at, bt = carry
        r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
        ks, vs, ns, okc = kv_from_products(
            r, c, v, jnp.minimum(n, prod_cap), shape, sr.add, stage_cap,
            mask=mask)
        at = _tile_permute(at, "col", _shift_perm(q, q, left=True))
        bt = _tile_permute(bt, "row", _shift_perm(q, q, left=True))
        return (at, bt), (ks, vs, ns, okx & okc)

    (_, _), (ks, vs, ns, oks) = jax.lax.scan(
        body, (a_skew, b_skew), None, length=q)
    items = [(ks[s], vs[s], ns[s]) for s in range(q)]
    k, v, nn, okm = kv_tree(items, sr.add, out_cap)
    c = kv_to_coo(k, v, nn, shape, sr.add, out_cap)
    return apply_val_pred(c, val_pred, ident), jnp.all(oks) & okm


def vals_dtype(sr, a_tile, b_tile):
    return sr.out_dtype(a_tile.dtype, b_tile.dtype)


def spgemm_2d(a: DistSpMat, b: DistSpMat, sr: Semiring = ARITHMETIC, *,
              mesh: Mesh, prod_cap: int, out_cap: int,
              variant: str = "rotation", merge: str = "deferred",
              mask: MaskSpec | None = None):
    """C = A ⊕.⊗ B (optionally C⟨M⟩). Returns (DistSpMat, ok[pr,pc]).

    ``mask.mat`` must be tile-aligned with C (same grid, C's shape): the
    mask never communicates, and each device prunes its expanded products
    against its own mask tile before any merge stage (§4.7).
    """
    assert a.grid == b.grid and a.pr == a.pc, "2D SpGEMM needs a square grid"
    assert a.shape[1] == b.shape[0]
    # operands are about to enter the rotation/allgather collectives: this
    # is the wire boundary the audit checksums bracket (and the fault sites
    # corrupt) — see robust/audit.guard_exchange
    a = _audit.guard_exchange("spgemm2d.comm_a", a)
    b = _audit.guard_exchange("spgemm2d.comm_b", b)
    q = a.pr
    mm = mask.mat if mask is not None else None
    val_pred = mask.val_pred if mask is not None else None
    if mask is not None and (mask.mat3 is not None or mask.vec is not None):
        raise ValueError("spgemm_2d takes a 2D mask operand (MaskSpec.mat)")
    if mm is not None:
        assert mm.grid == a.grid and mm.shape == (a.shape[0], b.shape[1]), \
            "mask must be tile-aligned with C"

    def body(at, bt, *mt):
        lm = mask.local(mt[0].tile()) if mt else None
        c, ok = _local_spgemm_2d(
            at.tile(), bt.tile(),
            sr, q, prod_cap, out_cap, variant, merge, mask=lm,
            val_pred=val_pred)
        return (c.row[None, None], c.col[None, None], c.val[None, None],
                c.nnz[None, None], ok[None, None])

    in_specs = (specs_of(a), specs_of(b))
    args = (a, b)
    if mm is not None:
        in_specs = in_specs + (specs_of(mm),)
        args = args + (mm,)
    out_specs = (P("row", "col", None), P("row", "col", None),
                 P("row", "col", None), P("row", "col"), P("row", "col"))
    f = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    row, col, val, nnz, ok = f(*args)
    # every merge path ends in dedup(order='row'), so C keeps the invariant
    cmat = DistSpMat(row, col, val, nnz, (a.shape[0], b.shape[1]), a.grid,
                     order="row")
    _audit.audit_obj(cmat, "spgemm2d.out", min_level=_audit.FULL)
    return cmat, ok


def spgemm_3d(a3: DistSpMat3D, b3: DistSpMat3D, sr: Semiring = ARITHMETIC, *,
              mesh: Mesh, prod_cap: int, out_cap: int,
              merge: str = "deferred", variant: str = "rotation",
              mask: MaskSpec | None = None):
    """Communication-avoiding SpGEMM on a (L, q, q) grid (paper Fig 2).

    Returns (C3 [dist='csub'], ok[L,q,q]).

    ``mask.mat3`` must be C-distributed ('csub', same grid). Each layer
    all-gathers the mask's L column sub-pieces of its C tile along the
    (cheap, nnz(M)-sized) 'layer' axis, so the per-layer 2D multiply prunes
    expanded products before any merge stage AND before the inter-layer
    all-to-all — masked entries never travel. ``mask.val_pred`` applies
    only after the inter-layer merge (layer partials are incomplete sums).
    """
    assert a3.dist == "acol" and b3.dist == "brow"
    assert a3.grid == b3.grid
    a3 = _audit.guard_exchange("spgemm3d.comm_a", a3)
    b3 = _audit.guard_exchange("spgemm3d.comm_b", b3)
    L, q = a3.L, a3.q
    tr_a, tc_a = a3.block_sizes()
    tr_b, tc_b = b3.block_sizes()
    assert tc_a == tr_b, (tc_a, tr_b)
    kbl = tc_b // L          # C column sub-block width after layer split
    c_shape = (a3.shape[0], b3.shape[1])
    m3 = mask.mat3 if mask is not None else None
    val_pred = mask.val_pred if mask is not None else None
    if mask is not None and (mask.mat is not None or mask.vec is not None):
        raise ValueError("spgemm_3d takes a 3D mask operand (MaskSpec.mat3)")
    if m3 is not None:
        assert m3.dist == "csub" and m3.grid == a3.grid \
            and m3.shape == c_shape, "mask must be C-distributed (csub)"
        if key_dtype((tr_a, tc_b)) is None:
            raise ValueError("masked 3D SpGEMM needs a packable C tile")

    def body(at, bt, *mt):
        a_tile = COO(at.row.reshape(-1), at.col.reshape(-1),
                     at.val.reshape(-1), at.nnz.reshape(()),
                     (tr_a, tc_a), a3.order)
        b_tile = COO(bt.row.reshape(-1), bt.col.reshape(-1),
                     bt.val.reshape(-1), bt.nnz.reshape(()),
                     (tr_b, tc_b), b3.order)
        lm = None
        if mt:
            # assemble the FULL C-tile mask from the L csub sub-pieces:
            # sub-piece l covers tile columns [l·kbl, (l+1)·kbl)
            mrow = jax.lax.all_gather(mt[0].row.reshape(-1), "layer")
            mcol = jax.lax.all_gather(mt[0].col.reshape(-1), "layer")
            mval = jax.lax.all_gather(mt[0].val.reshape(-1), "layer")
            fcol = jnp.where(
                mcol != SENTINEL,
                mcol + jnp.arange(L, dtype=jnp.int32)[:, None] * kbl,
                SENTINEL)
            keys = pack_keys(mrow.reshape(-1), fcol.reshape(-1),
                             (tr_a, tc_b), "row")
            if mask.pred is not None:
                allow = jnp.asarray(mask.pred(mval.reshape(-1))) \
                    & (mrow.reshape(-1) != SENTINEL)
                keys, allow = jax.lax.sort([keys, allow], num_keys=1,
                                           is_stable=False)
            else:
                allow = None
                keys = jax.lax.sort([keys], num_keys=1)[0]
            lm = LocalMask(keys, allow, mask.complement, "row")
        # per-layer 2D multiply ('row'/'col' collectives are layer-local)
        c_part, ok = _local_spgemm_2d(a_tile, b_tile, sr, q,
                                      prod_cap, prod_cap, variant, merge,
                                      mask=lm)
        # ---- inter-layer all-to-all (Fig 2, right) --------------------
        # destination layer of an entry = its column sub-block
        dest = jnp.where(c_part.mask(), c_part.col // kbl, L)
        cap_l = c_part.cap // L
        # radix-place each entry at dest*cap_l + rank_within_dest
        order = jnp.argsort(dest, stable=True)
        d_sorted = dest[order]
        seg_start = jnp.searchsorted(d_sorted, jnp.arange(L + 1),
                                     side="left").astype(jnp.int32)
        counts = seg_start[1:] - seg_start[:-1]
        ok = ok & jnp.all(counts <= cap_l)
        within = jnp.arange(c_part.cap, dtype=jnp.int32) - \
            seg_start[jnp.clip(d_sorted, 0, L - 1)]
        slot = jnp.where(d_sorted < L,
                         d_sorted * cap_l + jnp.minimum(within, cap_l - 1),
                         L * cap_l)  # dropped
        buf_r = jnp.full((L * cap_l,), SENTINEL, jnp.int32)
        buf_c = jnp.full((L * cap_l,), SENTINEL, jnp.int32)
        buf_v = jnp.full((L * cap_l,), sr.add.identity, c_part.val.dtype)
        keep = (d_sorted < L) & (within < cap_l)
        # dropped entries write out-of-bounds (mode='drop') — never a live slot
        slotk = jnp.where(keep, slot, L * cap_l)
        rs, cs_, vs = (c_part.row[order], c_part.col[order],
                       c_part.val[order])
        buf_r = buf_r.at[slotk].set(rs, mode="drop")
        buf_c = buf_c.at[slotk].set(cs_, mode="drop")
        buf_v = buf_v.at[slotk].set(vs, mode="drop")
        # exchange: piece t -> layer t
        def a2a(x):
            return jax.lax.all_to_all(x.reshape(L, cap_l), "layer", 0, 0,
                                      tiled=False).reshape(L * cap_l)
        buf_r, buf_c, buf_v = a2a(buf_r), a2a(buf_c), a2a(buf_v)
        my_layer = jax.lax.axis_index("layer")
        # localize columns to my sub-block and merge
        valid = buf_r != SENTINEL
        lc = jnp.where(valid, buf_c - my_layer * kbl, SENTINEL)
        lr = jnp.where(valid, buf_r, SENTINEL)
        if merge == "sort" or key_dtype((tr_a, kbl)) is None:
            # seed path: one dedup over the whole exchanged buffer
            d = COO(lr, lc, buf_v, jnp.sum(valid).astype(jnp.int32),
                    (tr_a, kbl), "none").dedup(sr.add)
            d = apply_val_pred(d, val_pred, sr.add.identity)
            ok = ok & (d.nnz <= out_cap)         # pre-clamp nnz
            merged = d.with_cap(out_cap, sr.add.identity)
        else:
            # merge engine (§4.4): each received piece is a stable-compacted
            # slice of a row-sorted dedup output, so the L chunks are
            # sorted unique-key streams — fold them pairwise, never re-sort
            items = []
            for t in range(L):
                sl = slice(t * cap_l, (t + 1) * cap_l)
                items.append((pack_keys(lr[sl], lc[sl], (tr_a, kbl), "row"),
                              buf_v[sl],
                              jnp.sum(valid[sl]).astype(jnp.int32)))
            k, v, nn, okm = kv_tree(items, sr.add, out_cap)
            merged = kv_to_coo(k, v, nn, (tr_a, kbl), sr.add, out_cap)
            merged = apply_val_pred(merged, val_pred, sr.add.identity)
            ok = ok & okm
        return (merged.row[None, None, None], merged.col[None, None, None],
                merged.val[None, None, None], merged.nnz[None, None, None],
                ok[None, None, None])

    in_specs = (specs_of(a3), specs_of(b3))
    args = (a3, b3)
    if m3 is not None:
        in_specs = in_specs + (specs_of(m3),)
        args = args + (m3,)
    out_specs = (P("layer", "row", "col", None),
                 P("layer", "row", "col", None),
                 P("layer", "row", "col", None),
                 P("layer", "row", "col"), P("layer", "row", "col"))
    f = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    row, col, val, nnz, ok = f(*args)
    c3 = DistSpMat3D(row, col, val, nnz, c_shape, a3.grid, "csub",
                     order="row")  # final inter-layer merge is a row dedup
    _audit.audit_obj(c3, "spgemm3d.out", min_level=_audit.FULL)
    return c3, ok


def spgemm_2d_batched(a: DistSpMat, b: DistSpMat, sr: Semiring = ARITHMETIC,
                      *, mesh: Mesh, prod_cap: int, out_cap: int,
                      nbatch: int, variant: str = "rotation",
                      mask: MaskSpec | None = None):
    """Batched SpGEMM (paper §7.2): form C in ``nbatch`` column batches.

    Each batch multiplies A by the column-slab restriction of B, yielding a
    DistSpMat for that slab; the caller consumes batches one at a time
    (HipMCL-style) so the full C never needs to exist in memory. Returns a
    list of (C_batch, ok) with C_batch's shape = full C shape (entries only
    in the slab).
    """
    nb_cols = b.nb  # tile width of B
    slab = -(-nb_cols // nbatch)
    outs = []
    for t in range(nbatch):
        bt = _restrict_cols(b, t * slab, slab)
        c, ok = spgemm_2d(a, bt, sr, mesh=mesh, prod_cap=prod_cap,
                          out_cap=out_cap, variant=variant, mask=mask)
        outs.append((c, ok))
    return outs


def _restrict_cols(b: DistSpMat, lo: int, width: int) -> DistSpMat:
    """Zero out entries outside tile-local columns [lo, lo+width)."""
    keep = (b.col >= lo) & (b.col < lo + width) & (b.col != SENTINEL)
    # compact each tile: sort kept-first along the cap axis; the stable
    # compaction preserves each tile's entry order, so the order tag survives
    order = jnp.argsort(~keep, axis=-1, stable=True)
    row = jnp.take_along_axis(jnp.where(keep, b.row, SENTINEL), order, -1)
    col = jnp.take_along_axis(jnp.where(keep, b.col, SENTINEL), order, -1)
    val = jnp.take_along_axis(jnp.where(keep, b.val, 0), order, -1)
    nnz = jnp.sum(keep, axis=-1).astype(jnp.int32)
    return DistSpMat(row, col, val, nnz, b.shape, b.grid, order=b.order)
