"""Distributed SpGEMM: 2D SUMMA (rotation + all-gather) and 3D CA (paper §3.2).

2D (paper's Sparse SUMMA, hardware-adapted — DESIGN.md §4.1, §4.8):
  - schedule='rotate' (variant='rotation', default): Cannon-style systolic
    schedule. One multi-axis collective-permute performs the initial skew,
    then q stages of neighbor rotation (A left along 'col', B up along 'row')
    each followed by a local O(flops) expansion. Communication volume per
    device equals the paper's Table 1 bandwidth term O(nnz(A+B)/√p); the
    primitive is the torus-native permute instead of an MPI broadcast.
  - schedule='alltoall' (variant='allgather'): the literal broadcast
    formulation — each device all-gathers its process row of A and process
    column of B in one shot, then runs the q local multiplies. Same volume,
    √q-deeper buffers (the memory/latency tradeoff the paper describes for
    2D SUMMA at scale).
  - schedule='bcast' / per-stage tuple (variant='hybrid'): SUMMA stage order
    k=s with a masked-psum broadcast per stage — O(1) extra buffering like
    'rotate' but addressable per stage, so a tuple schedule can batch its
    sparsest stages into ONE fused eager exchange ('gather' entries, the
    all-to-all leg of McFarland et al. arXiv 2504.06408) while streaming the
    dense stages as per-stage broadcasts.

Overlap (§4.8): by default (overlap=True) every stage loop is double
buffered — stage s+1's ppermute/psum is issued before stage s's local
expand+mask-filter+merge, so XLA can run the collective under the compute.
overlap=False reproduces the bulk-synchronous MPI model by pinning each
stage's merge outputs before the next exchange's inputs with an
optimization_barrier. Both orders run identical per-stage math, so their
results are bitwise equal (the overlap toggle is a pure scheduling choice).

Compressed exchanges (compress='int8'): value payloads are quantized to
per-tile symmetric int8 at the host boundary and travel the wire compressed
(the scale rides along in the fused tree permute); each stage dequantizes
just before expansion. Error feedback across spgemm_2d_batched batches
re-injects the quantization residual of A (re-sent every batch) so the
error does not accumulate. Requires floating values and an additive
identity of 0 (padding must survive the round trip). The int8 payload is
bracketed by the 'dist.compressed_exchange' audit/fault site.

3D CA (paper Fig 2): inputs on a (L, q, q) grid, A column-sliced and B
row-sliced across layers. Each layer runs an independent 2D multiply over a
contraction dim shrunk by L (broadcast/rotation volume shrinks by the
paper's √c factor on the smaller communicator), then one inter-layer
all-to-all scatters partial C column sub-blocks and a local semiring merge
forms C distributed like A. With overlap=True the three field exchanges are
fused into one tree-level all_to_all issued as soon as the radix placement
finishes; overlap=False barriers the placement and exchanges per field.

Merging (paper §5 "binary merge scheme", DESIGN.md §4.4): every stage
product buffer is compacted (per-stage packed-key dedup to
min(prod_cap, out_cap) slots) and then combined through the merge engine:

  merge='deferred'    pairwise merge tree over the q compacted stage
                      buffers — O(n) rank-placement merges, never a sort of
                      the q·prod_cap concatenation (and never of its
                      padding slack).
  merge='incremental' O(n) merge_sorted of each compacted stage into the
                      row-sorted accumulator (less memory, more steps).
  merge='sort'        the seed behavior — concatenate all q padded stage
                      buffers and dedup once. Kept for tiny problems (the
                      planner picks it when q·prod_cap is small) and as the
                      benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..dist.compression import quantize_payload
from ..obs import recorder as _obs
from ..robust import audit as _audit
from .compat import pvary, shard_map
from .coo import COO, SENTINEL
from .dist import DistSpMat, DistSpMat3D, specs_of
from .local_spgemm import _expand
from .mask import LocalMask, MaskSpec, apply_val_pred, filter_products
from .merge import (key_dtype, kv_empty, kv_from_products, kv_merge2,
                    kv_to_coo, kv_tree, merge_stage_products, pack_keys)
from .semiring import ARITHMETIC, Semiring

Array = jax.Array

# variant (planner-facing algorithm family) -> whole-sweep schedule
_VARIANT_SCHEDULE = {"rotation": "rotate", "allgather": "alltoall",
                     "hybrid": "bcast"}


def _schedule_from(variant, schedule, q):
    """Resolve the (variant, schedule) pair to an executable schedule.

    A schedule is either 'rotate' (whole-sweep Cannon), 'alltoall' (one-shot
    gather of all stage operands), 'bcast' (per-stage masked-psum broadcast,
    SUMMA stage order), or a length-q tuple of 'bcast'|'gather' picking the
    exchange per stage ('gather' stages are batched into one fused eager
    exchange). Cannon's rotation cannot be mixed per stage: after the skew,
    device (i,j) multiplies k=(i+j+s) mod q at stage s — a different k per
    device — while a broadcast stage needs the same k everywhere, so
    'rotate' is only available as a whole sweep (DESIGN.md §4.8).
    """
    if schedule is None:
        try:
            return _VARIANT_SCHEDULE[variant]
        except KeyError:
            raise ValueError(f"unknown SpGEMM variant {variant!r}") from None
    if isinstance(schedule, (tuple, list)):
        sched = tuple(schedule)
        if len(sched) != q:
            raise ValueError(
                f"per-stage schedule has {len(sched)} entries for q={q}")
        bad = [s for s in sched if s not in ("bcast", "gather")]
        if bad:
            raise ValueError(f"per-stage schedule entries must be "
                             f"'bcast'|'gather', got {bad!r}")
        return sched
    if schedule not in ("rotate", "alltoall", "bcast"):
        raise ValueError(f"unknown schedule {schedule!r}")
    return schedule


@lru_cache(maxsize=None)
def _cannon_perms(q, skew_a=True):
    """(src, dst) pairs on a row-major q×q grid for the initial skew.

    Memoized on q: the table is loop-invariant and trace-time constant, so
    it is built once per grid size instead of once per traced permute.
    """
    if skew_a:  # A(i, j) -> A(i, (j - i) mod q)
        return tuple((r * q + c, r * q + (c - r) % q)
                     for r in range(q) for c in range(q))
    # B(i, j) -> B((i - j) mod q, j)
    return tuple((r * q + c, ((r - c) % q) * q + c)
                 for r in range(q) for c in range(q))


@lru_cache(maxsize=None)
def _shift_perm(q, axis_len, left=True):
    return tuple((s, (s - 1) % axis_len) if left else (s, (s + 1) % axis_len)
                 for s in range(axis_len))


def _tile_permute(tile: COO, axes, perm, scale=None):
    """Move whole tiles between devices in ONE tree-level ppermute.

    All four fields (and the int8 dequantization scale, when the payload is
    compressed) travel in a single collective-permute instead of four — one
    launch, one fusion boundary. Each tile keeps its internal order.
    Returns (tile, scale); scale is None when no scale was passed.
    """
    fields = (tile.row, tile.col, tile.val, tile.nnz)
    if scale is None:
        r, c, v, n = jax.lax.ppermute(fields, axes, perm)
        return COO(r, c, v, n, tile.shape, tile.order), None
    r, c, v, n, s = jax.lax.ppermute(fields + (scale,), axes, perm)
    return COO(r, c, v, n, tile.shape, tile.order), s


def _deq(tile: COO, scale):
    """Dequantize an int8-compressed tile (identity when scale is None)."""
    if scale is None:
        return tile
    return COO(tile.row, tile.col, tile.val.astype(scale.dtype) * scale,
               tile.nnz, tile.shape, tile.order)


def _merge_products(rows, cols, vals, nvalid, shape, sr, out_cap,
                    order="row", val_pred=None):
    prods = COO(rows, cols, vals,
                jnp.minimum(nvalid, rows.shape[0]).astype(jnp.int32),
                shape, "none")
    d = prods.dedup(sr.add, order=order)
    d = apply_val_pred(d, val_pred, sr.add.identity)
    # overflow must be read from the PRE-clamp nnz: with_cap() truncates
    # nnz to out_cap, which would make this check vacuously true
    ok = d.nnz <= out_cap
    return d.with_cap(out_cap, sr.add.identity), ok


def _rotate_sweep(q, overlap, rotate, step, state0, at0, as0, bt0, bs0):
    """Run the q Cannon stages, double-buffered or bulk-synchronous.

    overlap=True: each scan iteration issues the NEXT rotation before the
    current stage's expand+merge, so XLA can run the permute under the
    compute; the epilogue stage multiplies the last operands without
    rotating them (the dead final rotation of the serial formulation is
    dropped — 1/q of the rotation volume).
    overlap=False: q iterations, each pinning its merge outputs before the
    next rotation's inputs with an optimization_barrier (the MPI
    bulk-synchronous model). Stage order and per-stage math are identical
    either way, so results are bitwise equal.

    ``step(state, at, bt) -> (state, y_or_None)`` consumes dequantized
    tiles; ``rotate`` moves the (possibly compressed) wire payload.
    """
    def deq_step(state, at, as_, bt, bs_):
        return step(state, _deq(at, as_), _deq(bt, bs_))

    if overlap:
        def body(carry, _):
            at, as_, bt, bs_, state = carry
            nxt = rotate(at, as_, bt, bs_)   # issued before this stage's work
            state, y = deq_step(state, at, as_, bt, bs_)
            return nxt + (state,), y

        (at, as_, bt, bs_, state), ys = jax.lax.scan(
            body, (at0, as0, bt0, bs0, state0), None, length=q - 1)
        state, y = deq_step(state, at, as_, bt, bs_)   # epilogue: no rotate
        if y is not None:
            ys = jax.tree.map(lambda s, e: jnp.concatenate([s, e[None]]),
                              ys, y)
        return state, ys

    def body(carry, _):
        at, as_, bt, bs_, state = carry
        state, y = deq_step(state, at, as_, bt, bs_)
        # bulk-synchronous: the next rotation may not launch until this
        # stage's merge has completed
        (state, y), (at, as_, bt, bs_) = jax.lax.optimization_barrier(
            ((state, y), (at, as_, bt, bs_)))
        return rotate(at, as_, bt, bs_) + (state,), y

    (_, _, _, _, state), ys = jax.lax.scan(
        body, (at0, as0, bt0, bs0, state0), None, length=q)
    return state, ys


def _staged_tail(outs, shape, sr, merge, prod_cap, stage_cap, out_cap,
                 mask, val_pred):
    """Merge q per-stage _expand outputs (shared by alltoall/bcast paths)."""
    ident = sr.add.identity
    ok = jnp.all(jnp.stack([o[4] for o in outs]))
    if merge == "sort":
        # seed path: concatenate q full padded buffers, sort once —
        # masked products are dropped per stage, before the concat
        if mask is not None:
            outs = [(*filter_products(r, c_, v, shape, mask, ident), n, o)
                    for (r, c_, v, n, o) in outs]
        rows = jnp.concatenate([o[0] for o in outs])
        cols = jnp.concatenate([o[1] for o in outs])
        vals = jnp.concatenate([o[2] for o in outs])
        total = sum(o[3] for o in outs)
        c, ok2 = _merge_products(rows, cols, vals, total, shape, sr,
                                 out_cap, val_pred=val_pred)
        return c, ok & ok2
    # merge engine: mask-filter + compact each stage, then fold the q
    # sorted streams
    c, okm = merge_stage_products(
        [(r, c_, v, jnp.minimum(n, prod_cap)) for (r, c_, v, n, _) in outs],
        shape, sr.add, stage_cap, out_cap, mask=mask)
    return apply_val_pred(c, val_pred, ident), ok & okm


def _local_spgemm_2d(a_tile: COO, b_tile: COO, sr, q, prod_cap, out_cap,
                     schedule, merge, overlap=True,
                     mask: LocalMask | None = None, val_pred=None,
                     a_scale=None, b_scale=None):
    """Body run per device under shard_map for the 2D algorithm.

    The engine paths ('deferred'/'incremental') run at the kv level:
    per-stage compaction to stage_cap = min(prod_cap, out_cap) — sound
    because a stage's distinct count is bounded by the final nnz(C), and
    checked pre-clamp by the ok flags — then rank-placement merging of the
    compacted streams, decoding rows/cols exactly once.

    ``mask`` prunes every stage's expanded products against the local mask
    tile BEFORE any merge stage (§4.7): a masked stage's distinct count is
    bounded by the masked nnz(C), so mask-sized out/stage caps stay sound
    (still guarded pre-clamp by the ok flags). ``val_pred`` drops merged
    entries by output value in the final compaction.

    ``a_scale``/``b_scale`` are per-tile int8 dequantization scales (scalar
    per device) when the value payload is compressed; tiles dequantize just
    before expansion, AFTER every collective, so the wire stays int8.
    """
    shape = (a_tile.shape[0], b_tile.shape[1])
    stage_cap = min(prod_cap, out_cap)
    ident = sr.add.identity
    if key_dtype(shape) is None:
        merge = "sort"        # unpackable tile: the engine needs x64 keys

    if schedule == "alltoall":
        # gather my process row of A and process column of B (the broadcast
        # formulation; all stages' operands live simultaneously)
        ar = jax.tree.map(lambda x: jax.lax.all_gather(x, "col"), a_tile)
        bc = jax.tree.map(lambda x: jax.lax.all_gather(x, "row"), b_tile)
        asg = None if a_scale is None else jax.lax.all_gather(a_scale, "col")
        bsg = None if b_scale is None else jax.lax.all_gather(b_scale, "row")
        if not overlap:
            # bulk-synchronous: every stage's operands must land before any
            # local multiply starts
            ar, bc, asg, bsg = jax.lax.optimization_barrier(
                (ar, bc, asg, bsg))

        def stage(s):
            at = COO(ar.row[s], ar.col[s], ar.val[s], ar.nnz[s],
                     a_tile.shape, a_tile.order)
            bt = COO(bc.row[s], bc.col[s], bc.val[s], bc.nnz[s],
                     b_tile.shape, b_tile.order)
            at = _deq(at, None if asg is None else asg[s])
            bt = _deq(bt, None if bsg is None else bsg[s])
            return _expand(at, bt, sr, prod_cap)

        outs = [stage(s) for s in range(q)]
        return _staged_tail(outs, shape, sr, merge, prod_cap, stage_cap,
                            out_cap, mask, val_pred)

    if schedule != "rotate":
        # hybrid SUMMA stage order k=s: per-stage masked-psum broadcast
        # ('bcast'), with the tuple schedule's 'gather' stages batched into
        # ONE fused eager exchange up front (the all-to-all leg)
        sched = (schedule if isinstance(schedule, tuple)
                 else ("bcast",) * q)
        ri = jax.lax.axis_index("row")
        ci = jax.lax.axis_index("col")
        apay = (a_tile, a_scale)
        bpay = (b_tile, b_scale)

        def sel(pay, pos, s):
            # only stage s's owner contributes; the psum reduces the zeros
            # away and delivers the owner's tile to the whole axis
            return jax.tree.map(
                lambda x: jnp.where(pos == s, x, jnp.zeros_like(x)), pay)

        gs = [s for s in range(q) if sched[s] == "gather"]
        eag_a = eag_b = None
        if gs:
            eag_a = jax.lax.psum(jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[sel(apay, ci, s) for s in gs]), "col")
            eag_b = jax.lax.psum(jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[sel(bpay, ri, s) for s in gs]), "row")

        def fetch(s):
            if s in gs:
                i = gs.index(s)
                return (jax.tree.map(lambda x: x[i], eag_a),
                        jax.tree.map(lambda x: x[i], eag_b))
            return (jax.lax.psum(sel(apay, ci, s), "col"),
                    jax.lax.psum(sel(bpay, ri, s), "row"))

        outs = []
        cur = fetch(0)
        for s in range(q):
            if overlap and s + 1 < q:
                nxt = fetch(s + 1)      # issued before this stage's expand
            (ap, asx), (bp, bsx) = cur
            y = _expand(_deq(ap, asx), _deq(bp, bsx), sr, prod_cap)
            if not overlap and s + 1 < q:
                # bulk-synchronous: the next broadcast's source payload may
                # not be read until this stage's expansion has completed
                y, (apay, bpay) = jax.lax.optimization_barrier(
                    (y, (apay, bpay)))
                nxt = fetch(s + 1)
            outs.append(y)
            if s + 1 < q:
                cur = nxt
        return _staged_tail(outs, shape, sr, merge, prod_cap, stage_cap,
                            out_cap, mask, val_pred)

    # rotation (Cannon)
    axes = ("row", "col")
    a_rot, as_rot = _tile_permute(a_tile, axes, _cannon_perms(q, True),
                                  a_scale)
    b_rot, bs_rot = _tile_permute(b_tile, axes, _cannon_perms(q, False),
                                  b_scale)
    if mask is not None:
        # loop-invariant closure of the scan bodies below: mark varying so
        # newer-jax manual-axes checks accept the device-local mask arrays
        mask = LocalMask(pvary(mask.keys, axes),
                         None if mask.allow is None
                         else pvary(mask.allow, axes),
                         mask.complement, mask.order)
    ok0 = pvary(jnp.bool_(True), axes)

    def rotate(at, as_, bt, bs_):
        at, as_ = _tile_permute(at, "col", _shift_perm(q, q, left=True), as_)
        bt, bs_ = _tile_permute(bt, "row", _shift_perm(q, q, left=True), bs_)
        return at, as_, bt, bs_

    if merge == "incremental":
        kacc, vacc, nacc = kv_empty(
            shape, out_cap, vals_dtype(sr, a_tile, b_tile, a_scale, b_scale),
            sr.add)
        # constants entering a shard_map scan carry must be marked varying
        # (newer jax; identity on 0.4.x — see compat.pvary)
        kacc, vacc, nacc = (pvary(kacc, axes), pvary(vacc, axes),
                            pvary(nacc, axes))

        def step(state, at, bt):
            kacc, vacc, nacc, ok = state
            r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
            # mask-filter + compact the stage, then O(n) rank-placement
            # merge into the sorted kv accumulator — never re-sorted
            ks, vs, ns, okc = kv_from_products(
                r, c, v, jnp.minimum(n, prod_cap), shape, sr.add, stage_cap,
                mask=mask)
            kacc, vacc, nacc, okm = kv_merge2(kacc, vacc, nacc, ks, vs, ns,
                                              sr.add, out_cap)
            return (kacc, vacc, nacc, ok & okx & okc & okm), None

        state, _ = _rotate_sweep(q, overlap, rotate, step,
                                 (kacc, vacc, nacc, ok0),
                                 a_rot, as_rot, b_rot, bs_rot)
        kacc, vacc, nacc, ok = state
        c = kv_to_coo(kacc, vacc, nacc, shape, sr.add, out_cap)
        return apply_val_pred(c, val_pred, ident), ok

    if merge == "sort":
        # seed path: collect q padded product buffers, concat, sort once
        def step(ok, at, bt):
            r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
            if mask is not None:
                r, c, v = filter_products(r, c, v, shape, mask, ident)
            return ok & okx, (r, c, v)

        ok, (rs, cs, vs) = _rotate_sweep(q, overlap, rotate, step, ok0,
                                         a_rot, as_rot, b_rot, bs_rot)
        rows = rs.reshape(-1)
        cols = cs.reshape(-1)
        vals = vs.reshape((-1,) + vs.shape[2:])
        c, ok2 = _merge_products(rows, cols, vals, rows.shape[0], shape, sr,
                                 out_cap, val_pred=val_pred)
        return c, ok & ok2

    # deferred (merge tree): mask-filter + compact each stage inside the
    # scan, then fold the q sorted kv streams pairwise — no concat-and-sort
    def step(ok, at, bt):
        r, c, v, n, okx = _expand(at, bt, sr, prod_cap)
        ks, vs, ns, okc = kv_from_products(
            r, c, v, jnp.minimum(n, prod_cap), shape, sr.add, stage_cap,
            mask=mask)
        return ok & okx & okc, (ks, vs, ns)

    ok, (ks, vs, ns) = _rotate_sweep(q, overlap, rotate, step, ok0,
                                     a_rot, as_rot, b_rot, bs_rot)
    items = [(ks[s], vs[s], ns[s]) for s in range(q)]
    k, v, nn, okm = kv_tree(items, sr.add, out_cap)
    c = kv_to_coo(k, v, nn, shape, sr.add, out_cap)
    return apply_val_pred(c, val_pred, ident), ok & okm


def vals_dtype(sr, a_tile, b_tile, a_scale=None, b_scale=None):
    # compressed tiles carry int8 on the wire; the scale keeps the
    # original value dtype, which is what expansion produces after deq
    ad = a_scale.dtype if a_scale is not None else a_tile.dtype
    bd = b_scale.dtype if b_scale is not None else b_tile.dtype
    return sr.out_dtype(ad, bd)


def _compress_operand(mat, sr, site, resid=None):
    """Quantize a DistSpMat's value payload to int8 at the host boundary.

    The returned matrix carries int8 values (the wire payload — guarded by
    the ``dist.compressed_exchange`` audit/fault site) plus a per-tile
    scale array; ``new_resid`` is the quantization error for error
    feedback (exactly val+resid − dequantized).
    """
    q8, scale, new_resid = quantize_payload(mat.val, mat.nnz, resid)
    if _obs.recording():
        # comm-volume tier: value-payload bytes before/after quantization
        # (the int8 wire adds one scale scalar per tile)
        import numpy as np
        live = int(np.sum(np.asarray(mat.nnz)))
        _obs.counter_add("dist.compress.bytes_in",
                         live * mat.val.dtype.itemsize)
        _obs.counter_add("dist.compress.bytes_out",
                         live * q8.dtype.itemsize
                         + scale.size * scale.dtype.itemsize)
    mat = dataclasses.replace(mat, val=q8)
    mat = _audit.guard_exchange(site, mat)
    return mat, scale, new_resid


@_obs.timed("spgemm2d")
def spgemm_2d(a: DistSpMat, b: DistSpMat, sr: Semiring = ARITHMETIC, *,
              mesh: Mesh, prod_cap: int, out_cap: int,
              variant: str = "rotation", merge: str = "deferred",
              mask: MaskSpec | None = None, schedule=None,
              overlap: bool = True, compress: str | None = None,
              ef_resid=None):
    """C = A ⊕.⊗ B (optionally C⟨M⟩). Returns (DistSpMat, ok[pr,pc]).

    ``schedule`` overrides the variant-derived exchange schedule: 'rotate',
    'alltoall', 'bcast', or a length-q tuple of 'bcast'|'gather' (§4.8).
    ``overlap`` toggles double-buffered (default) vs bulk-synchronous stage
    loops; results are bitwise equal either way. ``compress='int8'``
    quantizes the value payloads for the wire (floating values with an
    additive identity of 0 only); passing ``ef_resid`` (a residual array
    like ``a.val``, start with zeros) enables error feedback for A and
    makes the return a 3-tuple (C, ok, new_resid).

    ``mask.mat`` must be tile-aligned with C (same grid, C's shape): the
    mask never communicates, and each device prunes its expanded products
    against its own mask tile before any merge stage (§4.7).
    """
    assert a.grid == b.grid and a.pr == a.pc, "2D SpGEMM needs a square grid"
    assert a.shape[1] == b.shape[0]
    q = a.pr
    sched = _schedule_from(variant, schedule, q)
    # operands are about to enter the exchange collectives: this is the
    # wire boundary the audit checksums bracket (and the fault sites
    # corrupt) — see robust/audit.guard_exchange
    a = _audit.guard_exchange("spgemm2d.comm_a", a)
    b = _audit.guard_exchange("spgemm2d.comm_b", b)
    a_scale = b_scale = new_resid = None
    if ef_resid is not None and compress is None:
        raise ValueError("ef_resid is only meaningful with compress='int8'")
    if compress is not None:
        if compress != "int8":
            raise ValueError(f"unknown compress mode {compress!r}")
        if not (jnp.issubdtype(a.val.dtype, jnp.floating)
                and jnp.issubdtype(b.val.dtype, jnp.floating)):
            raise ValueError("compressed exchange needs floating values")
        if sr.add.identity != 0.0:
            raise ValueError(
                "compressed exchange needs an additive identity of 0 "
                "(padding must survive the int8 round trip)")
        with _obs.span("spgemm2d.compress"):
            a, a_scale, new_resid = _compress_operand(
                a, sr, "dist.compressed_exchange", ef_resid)
            b, b_scale, _ = _compress_operand(
                b, sr, "dist.compressed_exchange")
    mm = mask.mat if mask is not None else None
    val_pred = mask.val_pred if mask is not None else None
    if mask is not None and (mask.mat3 is not None or mask.vec is not None):
        raise ValueError("spgemm_2d takes a 2D mask operand (MaskSpec.mat)")
    if mm is not None:
        assert mm.grid == a.grid and mm.shape == (a.shape[0], b.shape[1]), \
            "mask must be tile-aligned with C"

    def body(at, bt, *extra):
        i = 0
        lm = None
        if mm is not None:
            lm = mask.local(extra[i].tile())
            i += 1
        asx = bsx = None
        if a_scale is not None:
            asx = extra[i].reshape(())
            bsx = extra[i + 1].reshape(())
        c, ok = _local_spgemm_2d(
            at.tile(), bt.tile(),
            sr, q, prod_cap, out_cap, sched, merge, overlap=overlap,
            mask=lm, val_pred=val_pred, a_scale=asx, b_scale=bsx)
        return (c.row[None, None], c.col[None, None], c.val[None, None],
                c.nnz[None, None], ok[None, None])

    in_specs = (specs_of(a), specs_of(b))
    args = (a, b)
    if mm is not None:
        in_specs = in_specs + (specs_of(mm),)
        args = args + (mm,)
    if a_scale is not None:
        in_specs = in_specs + (P("row", "col"), P("row", "col"))
        args = args + (a_scale, b_scale)
    out_specs = (P("row", "col", None), P("row", "col", None),
                 P("row", "col", None), P("row", "col"), P("row", "col"))
    f = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    # the SUMMA stage loop itself is traced (inside shard_map) — the host
    # span brackets the whole dispatch, blocking when recording so the
    # span covers device execution, not just async dispatch
    with _obs.span("spgemm2d.execute", q=q, variant=variant, merge=merge,
                   schedule=sched if isinstance(sched, str) else "hybrid",
                   overlap=overlap, compress=compress or "none"):
        row, col, val, nnz, ok = f(*args)
        _obs.sync((row, col, val, nnz, ok))
    # every merge path ends in dedup(order='row'), so C keeps the invariant
    cmat = DistSpMat(row, col, val, nnz, (a.shape[0], b.shape[1]), a.grid,
                     order="row")
    _audit.audit_obj(cmat, "spgemm2d.out", min_level=_audit.FULL)
    if ef_resid is not None:
        return cmat, ok, new_resid
    return cmat, ok


@_obs.timed("spgemm3d")
def spgemm_3d(a3: DistSpMat3D, b3: DistSpMat3D, sr: Semiring = ARITHMETIC, *,
              mesh: Mesh, prod_cap: int, out_cap: int,
              merge: str = "deferred", variant: str = "rotation",
              mask: MaskSpec | None = None, schedule=None,
              overlap: bool = True):
    """Communication-avoiding SpGEMM on a (L, q, q) grid (paper Fig 2).

    Returns (C3 [dist='csub'], ok[L,q,q]). ``schedule``/``overlap`` select
    the per-layer 2D exchange schedule and double-buffering exactly as in
    :func:`spgemm_2d`; ``overlap`` additionally fuses the inter-layer
    all-to-all into one tree-level exchange (overlap=False barriers the
    radix placement and exchanges the three fields separately — the
    bulk-synchronous reference; both move identical bytes, results are
    bitwise equal).

    ``mask.mat3`` must be C-distributed ('csub', same grid). Each layer
    all-gathers the mask's L column sub-pieces of its C tile along the
    (cheap, nnz(M)-sized) 'layer' axis, so the per-layer 2D multiply prunes
    expanded products before any merge stage AND before the inter-layer
    all-to-all — masked entries never travel. ``mask.val_pred`` applies
    only after the inter-layer merge (layer partials are incomplete sums).
    """
    assert a3.dist == "acol" and b3.dist == "brow"
    assert a3.grid == b3.grid
    a3 = _audit.guard_exchange("spgemm3d.comm_a", a3)
    b3 = _audit.guard_exchange("spgemm3d.comm_b", b3)
    L, q = a3.L, a3.q
    sched = _schedule_from(variant, schedule, q)
    tr_a, tc_a = a3.block_sizes()
    tr_b, tc_b = b3.block_sizes()
    assert tc_a == tr_b, (tc_a, tr_b)
    kbl = tc_b // L          # C column sub-block width after layer split
    c_shape = (a3.shape[0], b3.shape[1])
    m3 = mask.mat3 if mask is not None else None
    val_pred = mask.val_pred if mask is not None else None
    if mask is not None and (mask.mat is not None or mask.vec is not None):
        raise ValueError("spgemm_3d takes a 3D mask operand (MaskSpec.mat3)")
    if m3 is not None:
        assert m3.dist == "csub" and m3.grid == a3.grid \
            and m3.shape == c_shape, "mask must be C-distributed (csub)"
        if key_dtype((tr_a, tc_b)) is None:
            raise ValueError("masked 3D SpGEMM needs a packable C tile")

    def body(at, bt, *mt):
        a_tile = COO(at.row.reshape(-1), at.col.reshape(-1),
                     at.val.reshape(-1), at.nnz.reshape(()),
                     (tr_a, tc_a), a3.order)
        b_tile = COO(bt.row.reshape(-1), bt.col.reshape(-1),
                     bt.val.reshape(-1), bt.nnz.reshape(()),
                     (tr_b, tc_b), b3.order)
        lm = None
        if mt:
            # assemble the FULL C-tile mask from the L csub sub-pieces:
            # sub-piece l covers tile columns [l·kbl, (l+1)·kbl)
            mrow = jax.lax.all_gather(mt[0].row.reshape(-1), "layer")
            mcol = jax.lax.all_gather(mt[0].col.reshape(-1), "layer")
            mval = jax.lax.all_gather(mt[0].val.reshape(-1), "layer")
            fcol = jnp.where(
                mcol != SENTINEL,
                mcol + jnp.arange(L, dtype=jnp.int32)[:, None] * kbl,
                SENTINEL)
            keys = pack_keys(mrow.reshape(-1), fcol.reshape(-1),
                             (tr_a, tc_b), "row")
            if mask.pred is not None:
                allow = jnp.asarray(mask.pred(mval.reshape(-1))) \
                    & (mrow.reshape(-1) != SENTINEL)
                keys, allow = jax.lax.sort([keys, allow], num_keys=1,
                                           is_stable=False)
            else:
                allow = None
                keys = jax.lax.sort([keys], num_keys=1)[0]
            lm = LocalMask(keys, allow, mask.complement, "row")
        # per-layer 2D multiply ('row'/'col' collectives are layer-local)
        c_part, ok = _local_spgemm_2d(a_tile, b_tile, sr, q,
                                      prod_cap, prod_cap, sched, merge,
                                      overlap=overlap, mask=lm)
        # ---- inter-layer all-to-all (Fig 2, right) --------------------
        # destination layer of an entry = its column sub-block
        dest = jnp.where(c_part.mask(), c_part.col // kbl, L)
        cap_l = c_part.cap // L
        # radix-place each entry at dest*cap_l + rank_within_dest
        order = jnp.argsort(dest, stable=True)
        d_sorted = dest[order]
        seg_start = jnp.searchsorted(d_sorted, jnp.arange(L + 1),
                                     side="left").astype(jnp.int32)
        counts = seg_start[1:] - seg_start[:-1]
        ok = ok & jnp.all(counts <= cap_l)
        within = jnp.arange(c_part.cap, dtype=jnp.int32) - \
            seg_start[jnp.clip(d_sorted, 0, L - 1)]
        slot = jnp.where(d_sorted < L,
                         d_sorted * cap_l + jnp.minimum(within, cap_l - 1),
                         L * cap_l)  # dropped
        buf_r = jnp.full((L * cap_l,), SENTINEL, jnp.int32)
        buf_c = jnp.full((L * cap_l,), SENTINEL, jnp.int32)
        buf_v = jnp.full((L * cap_l,), sr.add.identity, c_part.val.dtype)
        keep = (d_sorted < L) & (within < cap_l)
        # dropped entries write out-of-bounds (mode='drop') — never a live slot
        slotk = jnp.where(keep, slot, L * cap_l)
        rs, cs_, vs = (c_part.row[order], c_part.col[order],
                       c_part.val[order])
        buf_r = buf_r.at[slotk].set(rs, mode="drop")
        buf_c = buf_c.at[slotk].set(cs_, mode="drop")
        buf_v = buf_v.at[slotk].set(vs, mode="drop")
        # exchange: piece t -> layer t
        if overlap:
            # one fused tree-level all-to-all, issued as soon as the radix
            # placement finishes — XLA can overlap it with the argsort of
            # the next shard_map program and fuses three launches into one
            buf_r, buf_c, buf_v = jax.lax.all_to_all(
                (buf_r.reshape(L, cap_l), buf_c.reshape(L, cap_l),
                 buf_v.reshape(L, cap_l)), "layer", 0, 0, tiled=False)
            buf_r = buf_r.reshape(L * cap_l)
            buf_c = buf_c.reshape(L * cap_l)
            buf_v = buf_v.reshape(L * cap_l)
        else:
            # bulk-synchronous reference: placement completes, then three
            # separate per-field exchanges
            buf_r, buf_c, buf_v = jax.lax.optimization_barrier(
                (buf_r, buf_c, buf_v))

            def a2a(x):
                return jax.lax.all_to_all(x.reshape(L, cap_l), "layer", 0, 0,
                                          tiled=False).reshape(L * cap_l)

            buf_r, buf_c, buf_v = a2a(buf_r), a2a(buf_c), a2a(buf_v)
        my_layer = jax.lax.axis_index("layer")
        # localize columns to my sub-block and merge
        valid = buf_r != SENTINEL
        lc = jnp.where(valid, buf_c - my_layer * kbl, SENTINEL)
        lr = jnp.where(valid, buf_r, SENTINEL)
        if merge == "sort" or key_dtype((tr_a, kbl)) is None:
            # seed path: one dedup over the whole exchanged buffer
            d = COO(lr, lc, buf_v, jnp.sum(valid).astype(jnp.int32),
                    (tr_a, kbl), "none").dedup(sr.add)
            d = apply_val_pred(d, val_pred, sr.add.identity)
            ok = ok & (d.nnz <= out_cap)         # pre-clamp nnz
            merged = d.with_cap(out_cap, sr.add.identity)
        else:
            # merge engine (§4.4): each received piece is a stable-compacted
            # slice of a row-sorted dedup output, so the L chunks are
            # sorted unique-key streams — fold them pairwise, never re-sort
            items = []
            for t in range(L):
                sl = slice(t * cap_l, (t + 1) * cap_l)
                items.append((pack_keys(lr[sl], lc[sl], (tr_a, kbl), "row"),
                              buf_v[sl],
                              jnp.sum(valid[sl]).astype(jnp.int32)))
            k, v, nn, okm = kv_tree(items, sr.add, out_cap)
            merged = kv_to_coo(k, v, nn, (tr_a, kbl), sr.add, out_cap)
            merged = apply_val_pred(merged, val_pred, sr.add.identity)
            ok = ok & okm
        return (merged.row[None, None, None], merged.col[None, None, None],
                merged.val[None, None, None], merged.nnz[None, None, None],
                ok[None, None, None])

    in_specs = (specs_of(a3), specs_of(b3))
    args = (a3, b3)
    if m3 is not None:
        in_specs = in_specs + (specs_of(m3),)
        args = args + (m3,)
    out_specs = (P("layer", "row", "col", None),
                 P("layer", "row", "col", None),
                 P("layer", "row", "col", None),
                 P("layer", "row", "col"), P("layer", "row", "col"))
    f = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    with _obs.span("spgemm3d.execute", L=L, q=q, variant=variant,
                   merge=merge, overlap=overlap):
        row, col, val, nnz, ok = f(*args)
        _obs.sync((row, col, val, nnz, ok))
    c3 = DistSpMat3D(row, col, val, nnz, c_shape, a3.grid, "csub",
                     order="row")  # final inter-layer merge is a row dedup
    _audit.audit_obj(c3, "spgemm3d.out", min_level=_audit.FULL)
    return c3, ok


def spgemm_2d_batched(a: DistSpMat, b: DistSpMat, sr: Semiring = ARITHMETIC,
                      *, mesh: Mesh, prod_cap: int, out_cap: int,
                      nbatch: int, variant: str = "rotation",
                      mask: MaskSpec | None = None, schedule=None,
                      overlap: bool = True, compress: str | None = None):
    """Batched SpGEMM (paper §7.2): form C in ``nbatch`` column batches.

    Each batch multiplies A by the column-slab restriction of B, yielding a
    DistSpMat for that slab; the caller consumes batches one at a time
    (HipMCL-style) so the full C never needs to exist in memory. Returns a
    list of (C_batch, ok) with C_batch's shape = full C shape (entries only
    in the slab).

    With ``compress='int8'`` the quantization residual of A (re-sent every
    batch) is carried across batches as error feedback, so A's wire error
    does not accumulate over the batch loop.
    """
    nb_cols = b.nb  # tile width of B
    slab = -(-nb_cols // nbatch)
    resid = jnp.zeros_like(a.val) if compress is not None else None
    outs = []
    for t in range(nbatch):
        with _obs.span("spgemm2d.batch", batch=t, nbatch=nbatch):
            bt = _restrict_cols(b, t * slab, slab)
            if compress is not None:
                c, ok, resid = spgemm_2d(
                    a, bt, sr, mesh=mesh, prod_cap=prod_cap,
                    out_cap=out_cap, variant=variant, mask=mask,
                    schedule=schedule, overlap=overlap, compress=compress,
                    ef_resid=resid)
            else:
                c, ok = spgemm_2d(a, bt, sr, mesh=mesh, prod_cap=prod_cap,
                                  out_cap=out_cap, variant=variant,
                                  mask=mask, schedule=schedule,
                                  overlap=overlap)
            outs.append((c, ok))
    return outs


def _restrict_cols(b: DistSpMat, lo: int, width: int) -> DistSpMat:
    """Zero out entries outside tile-local columns [lo, lo+width)."""
    keep = (b.col >= lo) & (b.col < lo + width) & (b.col != SENTINEL)
    # compact each tile: sort kept-first along the cap axis; the stable
    # compaction preserves each tile's entry order, so the order tag survives
    order = jnp.argsort(~keep, axis=-1, stable=True)
    row = jnp.take_along_axis(jnp.where(keep, b.row, SENTINEL), order, -1)
    col = jnp.take_along_axis(jnp.where(keep, b.col, SENTINEL), order, -1)
    val = jnp.take_along_axis(jnp.where(keep, b.val, 0), order, -1)
    nnz = jnp.sum(keep, axis=-1).astype(jnp.int32)
    return DistSpMat(row, col, val, nnz, b.shape, b.grid, order=b.order)
