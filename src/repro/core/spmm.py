"""Distributed SpMM: sparse × tall-skinny dense (paper §1 "1.5D SpMM", [16]).

The dense matrix X (n × k, k small) uses the *superimposed* vector
distribution (row-split only — a DistVec whose elements are rows of X, i.e.
``vdims=(k,)``). The A-stationary 1.5D algorithm communicates only the two
dense matrices (X gather + Y reduce-scatter), never the sparse matrix —
the paper's stated reason this distribution wins for tall-skinny X.

  1. all-gather X pieces along 'row'  → X block x_j      (nb, k)
  2. local SpMM (col-partitioned products + row-segment reduce)
  3. psum_scatter partial Y along 'col' → Y pieces, layout 'row'

Cost per device: O(k·nnz/p) compute, O(k(m+n)/√p) bandwidth — Table 1 row 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map
from .coo import COO
from .dist import DistSpMat, DistVec, specs_of
from .semiring import ARITHMETIC, Semiring, segment_reduce

Array = jax.Array


def local_spmm(a: COO, x: Array, sr: Semiring = ARITHMETIC) -> Array:
    """Y[i, :] = ⊕_j mul(A[i,j], X[j, :]) for dense X (nb, k)."""
    sa = a.sort("row")
    xr = x[jnp.clip(sa.col, 0, x.shape[0] - 1)]          # (cap, k)
    prod = sr.mul(sa.val[:, None], xr)
    ids = jnp.where(sa.mask(), sa.row, a.shape[0])
    return segment_reduce(prod, ids, a.shape[0], sr.add, sorted_ids=True)


def spmm_15d(a: DistSpMat, x: DistVec, sr: Semiring = ARITHMETIC, *,
             mesh: Mesh) -> DistVec:
    """Y = A X, X a DistVec with vdims=(k,) in layout 'col'."""
    assert x.layout == "col"
    pr, pc = a.grid

    def body(at, xd):
        tile = at.tile()
        xj = jax.lax.all_gather(xd.reshape((-1,) + xd.shape[3:]), "row",
                                tiled=True)              # (nb, k)
        y_part = local_spmm(tile, xj, sr)                # (mb, k)
        if sr.add.tag == "sum":
            y_piece = jax.lax.psum_scatter(y_part, "col",
                                           scatter_dimension=0, tiled=True)
        else:
            parts = jax.lax.all_gather(y_part, "col")
            red = parts[0]
            for t in range(1, pc):
                red = sr.add.op(red, parts[t])
            j = jax.lax.axis_index("col")
            y_piece = red.reshape((pc, -1) + red.shape[1:])[j]
        return y_piece[None, None]

    out = shard_map(body, mesh=mesh,
                        in_specs=(specs_of(a), P("row", "col", None, None)),
                        out_specs=P("row", "col", None, None))(a, x.data)
    return DistVec(out, a.shape[0], a.grid, "row")


def spmm_2d(a: DistSpMat, x: Array, sr: Semiring = ARITHMETIC, *,
            mesh: Mesh) -> Array:
    """True-2D SpMM: X 2D-block distributed (the paper's "true 2D
    distribution ... for other dense matrices").

    X: (nb·pc, k) sharded P('col', 'row'): device (i, j) owns X's row block
    j (matching A's tile columns) restricted to k-panel i — a genuine 2D
    split of the dense operand. The k-panels of block j are all-gathered
    along 'row' (X moves O(k·n/√p) bytes/device) and partial Y is
    reduce-scattered along 'col' (O(k·m/√p)) — together the paper's Table 1
    SpMM bandwidth O(k(m+n)/√p). The sparse matrix never moves.

    Output: (mb·pc, k) sharded P(('row','col'), None) — Y rows fully
    distributed in 'row' layout.
    """
    pr, pc = a.grid
    k = x.shape[1]
    assert k % pr == 0, "k must divide the process-row count"
    assert x.shape[0] == a.nb * pc, (x.shape, a.nb, pc)

    def body(at, xd):
        tile = at.tile()
        # xd: (nb, k/pr) — column block j, k-panel i; gather full k
        xj = jax.lax.all_gather(xd, "row", axis=1, tiled=True)  # (nb, k)
        y_part = local_spmm(tile, xj, sr)                # (mb, k)
        if sr.add.tag == "sum":
            y = jax.lax.psum_scatter(y_part, "col", scatter_dimension=0,
                                     tiled=True)         # (mb/pc, k)
        else:
            parts = jax.lax.all_gather(y_part, "col")
            red = parts[0]
            for t in range(1, pc):
                red = sr.add.op(red, parts[t])
            j = jax.lax.axis_index("col")
            y = red.reshape((pc, -1) + red.shape[1:])[j]
        return y

    return shard_map(body, mesh=mesh,
                         in_specs=(specs_of(a), P("col", "row")),
                         out_specs=P(("row", "col"), None))(a, x)
