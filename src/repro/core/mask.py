"""Output masks for SpGEMM / SpMSpV — GraphBLAS C⟨M⟩ semantics, pushed down.

CombBLAS 2.0's biggest application wins (masked triangle counting, HipMCL
pruning, direction-optimized BFS) come from discarding non-mask products
*during* the multiply, not after it: the mask is a core primitive of the
GraphBLAS model, not a post-filter. This module makes that first-class:

  **MaskSpec** — the user-facing description of an output mask:
    - *structural*  keep C entries whose (row, col) is stored in a mask
                    matrix M (tile-aligned with C — no communication);
    - *complement*  keep entries NOT stored in M;
    - *pred*        sub-select which stored M entries count as members
                    (a predicate over the mask's values);
    - *vector*      for SpMSpV: membership is ``pred(m[row])`` over a dense
                    ``DistVec`` in the output's piece layout (BFS passes the
                    visited/levels vector here, complemented);
    - *val_pred*    a predicate over the OUTPUT values, applied inside the
                    merge pipeline's final compaction (fused GraphBLAS
                    select — HipMCL's prune). Unlike the pattern masks it
                    cannot shrink merge capacities (selectivity is unknown
                    until values exist), but it removes the separate prune
                    pass and keeps the returned tile small.

  **LocalMask** — the per-tile device representation: the mask tile's
  (row, col) pairs packed into ONE sorted integer key array (reusing the
  merge engine's ``pack_keys``), plus an optional ``allow`` payload for
  value-predicate sub-selection. Membership of a candidate entry is a
  vectorized sorted probe: one ``searchsorted`` against the mask keys —
  O(log nnz(M)) per candidate, no densification of the mask.

Where the filter runs (DESIGN.md §4.7): expanded products are filtered
against the LocalMask *before any merge stage* — before the per-stage kv
compaction on the engine paths (``merge.kv_from_products(mask=...)``),
before the concat-and-sort on the legacy path — so the planner can size
``out_cap`` (and therefore every stage compaction and merge-tree slot
count) from the mask-intersected nnz estimate instead of the full nnz(C).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .coo import COO, SENTINEL
from .dist import DistSpMat, DistSpMat3D, DistVec
from .merge import _unpack, key_dtype, pack_keys

Array = jax.Array


# --------------------------------------------------------------------------
# local (per-tile) mask: sorted packed keys + membership probe
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LocalMask:
    """Sorted packed-key view of one mask tile (device-resident).

    ``keys`` are ascending with dtype-max padding (the pack_keys contract);
    ``allow`` (optional) marks which mask entries count as members — kept as
    a payload aligned with ``keys`` so value-predicate masks never need a
    re-sort. ``complement`` flips membership for live candidates. ``order``
    records the key packing ('row'/'col'); probes pack candidates with the
    SAME order, so callers running a different sort order still probe
    correctly.
    """

    keys: Array                       # (mask_cap,) sorted packed keys
    allow: Optional[Array]            # (mask_cap,) bool or None
    complement: bool = dataclasses.field(
        default=False, metadata=dict(static=True))
    order: str = dataclasses.field(
        default="row", metadata=dict(static=True))


def local_mask(tile: COO, *, pred: Callable | None = None,
               complement: bool = False, order: str = "row") -> LocalMask:
    """Build a LocalMask from a canonical (deduplicated) mask tile.

    Row-sorted tiles (the §4.3 invariant) pack for free; untagged tiles pay
    one packed argsort of the mask — never of the products it will filter.
    """
    if key_dtype(tile.shape) is None:
        raise ValueError(
            "masked kernels need a packable tile key space "
            f"(shape {tile.shape}); increase the process grid "
            "(paper §1, 32-bit local indices)")
    t = tile if tile.order == order else tile.sort(order)
    keys = pack_keys(t.row, t.col, t.shape, order)
    allow = None
    if pred is not None:
        allow = jnp.asarray(pred(t.val)).reshape(t.cap, -1).all(axis=-1) \
            & t.mask()
    return LocalMask(keys, allow, complement, order)


def mask_member(keys: Array, m: LocalMask) -> Array:
    """Vectorized sorted-membership probe.

    ``keys``: packed candidate keys (dtype-max = padding). Returns the KEEP
    flags under the mask semantics: padding is never kept; live candidates
    keep iff stored-and-allowed in the mask (xor complement).
    """
    kmax = jnp.asarray(jnp.iinfo(keys.dtype).max, keys.dtype)
    mk = m.keys.astype(keys.dtype)
    pos = jnp.searchsorted(mk, keys, side="left").astype(jnp.int32)
    posc = jnp.clip(pos, 0, mk.shape[0] - 1)
    live = keys != kmax
    hit = (mk[posc] == keys) & live
    if m.allow is not None:
        hit = hit & m.allow[posc]
    keep = (live & ~hit) if m.complement else hit
    return keep


def filter_products(rows: Array, cols: Array, vals: Array, shape,
                    m: LocalMask, identity):
    """Drop expanded products failing the mask (pre-merge pushdown).

    Dropped entries become canonical padding in place (SENTINEL coords,
    identity value) — downstream dedup/kv compaction already treats them as
    slack, so no re-compaction sort is needed here. Candidate keys pack
    with the MASK's order, whatever sort order the caller runs in.
    """
    keys = pack_keys(rows, cols, shape, m.order)
    keep = mask_member(keys, m)
    vdims = vals.shape[1:]
    km = keep.reshape((-1,) + (1,) * len(vdims))
    return (jnp.where(keep, rows, SENTINEL),
            jnp.where(keep, cols, SENTINEL),
            jnp.where(km, vals, jnp.asarray(identity, vals.dtype)))


def filter_tile(c: COO, m: LocalMask, identity) -> COO:
    """Post-hoc mask application to a MERGED tile (the postfilter fallback).

    Same semantics as pushing the mask through the multiply
    (``filter_products``), applied after the fact instead — the degradation
    ladder's first rung (robust/recover.py) computes C unmasked and calls
    this per tile. Stable compaction preserves the tile's order tag.
    """
    keys = pack_keys(c.row, c.col, c.shape, m.order)
    keep = mask_member(keys, m)
    return c.prune(lambda _v: keep, fill=identity)


def mask_dense(m: LocalMask, shape) -> Array:
    """Dense boolean member matrix (the dense-accumulator kernel's view)."""
    kmax = jnp.iinfo(m.keys.dtype).max
    valid = m.keys != kmax
    if m.allow is not None:
        valid = valid & m.allow
    row, col = _unpack(jnp.where(valid, m.keys, 0), shape, m.order)
    mem = jnp.zeros(shape, bool).at[row, col].max(valid, mode="drop")
    return ~mem if m.complement else mem


def apply_val_pred(c: COO, val_pred: Callable | None, identity) -> COO:
    """Fused output-value select: drop merged entries failing ``val_pred``.

    Runs after duplicate fusion (values are final) and before the caller's
    capacity clamp — the merge pipeline's last compaction stage.
    """
    if val_pred is None:
        return c
    keep = jnp.asarray(val_pred(c.val)).reshape(c.cap, -1).all(axis=-1)
    return c.prune(lambda _v: keep, fill=identity)


# --------------------------------------------------------------------------
# distributed mask description
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Output mask for a distributed multiply. Build via the constructors
    below (``structural`` / ``complement_of`` / ``vector_mask`` /
    ``value_mask``); exactly one pattern operand (or none, for a pure
    value mask) may be set, and it must be tile/piece-aligned with the
    output — masks never communicate.
    """

    mat: DistSpMat | None = None      # SpGEMM 2D pattern operand
    mat3: DistSpMat3D | None = None   # SpGEMM 3D pattern operand ('csub')
    vec: DistVec | None = None        # SpMSpV row-membership operand
    complement: bool = False
    pred: Callable | None = None      # over mask operand values
    val_pred: Callable | None = None  # over OUTPUT values (fused select)

    def __post_init__(self):
        operands = sum(x is not None for x in (self.mat, self.mat3, self.vec))
        if operands > 1:
            raise ValueError("MaskSpec takes at most one pattern operand")
        if operands == 0 and self.val_pred is None:
            raise ValueError("empty MaskSpec: no pattern operand, no val_pred")
        if self.vec is not None and self.pred is None:
            raise ValueError(
                "dense-vector masks need pred to define membership")

    def local(self, tile: COO) -> LocalMask:
        """LocalMask over one (already localized) mask tile."""
        return local_mask(tile, pred=self.pred, complement=self.complement)


def structural(m: DistSpMat | DistSpMat3D, *, complement: bool = False,
               pred: Callable | None = None,
               val_pred: Callable | None = None) -> MaskSpec:
    """Keep output entries stored in ``m`` (complement: NOT stored)."""
    if isinstance(m, DistSpMat3D):
        return MaskSpec(mat3=m, complement=complement, pred=pred,
                        val_pred=val_pred)
    return MaskSpec(mat=m, complement=complement, pred=pred,
                    val_pred=val_pred)


def complement_of(m: DistSpMat | DistSpMat3D, *,
                  pred: Callable | None = None,
                  val_pred: Callable | None = None) -> MaskSpec:
    return structural(m, complement=True, pred=pred, val_pred=val_pred)


def vector_mask(v: DistVec, pred: Callable, *,
                complement: bool = False) -> MaskSpec:
    """SpMSpV row mask: keep output rows where ``pred(v[row])`` (xor
    complement). ``v`` must be piece-aligned with the output vector
    (layout 'row' on the matrix grid) — BFS passes visited levels here."""
    return MaskSpec(vec=v, complement=complement, pred=pred)


def value_mask(val_pred: Callable) -> MaskSpec:
    """Pure output-value mask (fused GraphBLAS select, e.g. HipMCL prune)."""
    return MaskSpec(val_pred=val_pred)


def mask_allowed_count(mask: MaskSpec) -> int | None:
    """Host-side count of mask-admissible output slots (planner input).

    Vector masks: number of admissible rows. Pattern masks are accounted
    per-tile by ``plan_spgemm`` instead (this returns None for them).
    """
    if mask.vec is None:
        return None
    member = jnp.asarray(mask.pred(mask.vec.data))
    if mask.complement:
        member = ~member
    return int(jax.device_get(jnp.sum(member)))
