"""Local SpMV / SpMSpV algorithm families (paper §4.2–4.3, Fig 3).

The paper's multithreaded variants are reproduced as algorithmic variants
(the thread-partitioning dimension changes the data structures and memory
traffic, not just the parallel schedule — see DESIGN.md §4.5):

SpMV  (dense x):
  - ``spmv_row``  row-partitioned: requires row-major tile; per-row segments
                  reduced in order (no scatter, streaming output — the
                  paper's "better locality on y, whole x read").
  - ``spmv_col``  col-partitioned: col-major tile, products scattered into a
                  thread-private-accumulator analogue (dense scatter-add;
                  only the owned x slice is read — the paper's tradeoff).

SpMSpV (sparse x, f = nnz(x)):
  - ``spmspv_sort``   merge products by sorting (heap-analogue; best very
                      sparse vectors).
  - ``spmspv_spa``    dense SPA accumulator + re-sparsify (best dense-ish).
  - ``spmspv_bucket`` propagation blocking [Beamer et al.]: products are
                      first binned by row-bucket, then each bucket is merged
                      in a bucket-local SPA (the paper's SpMSpV-Bucket).

All variants cost O(f + df) work like the paper's, accept arbitrary
semirings, and return (sparse_y, ok_overflow_flag).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .coo import COO, SENTINEL, column_range
from .semiring import ARITHMETIC, Monoid, Semiring, segment_reduce

Array = jax.Array


# --------------------------------------------------------------------------
# sparse vector container (FullyDistSpVec's local piece)
# --------------------------------------------------------------------------

def spvec(idx: Array, val: Array, n: int, nnz=None):
    """Canonical padded sparse vector: (idx[i32 cap], val[cap], nnz)."""
    idx = jnp.asarray(idx, jnp.int32)
    nnz = jnp.asarray(idx.shape[0] if nnz is None else nnz, jnp.int32)
    mask = jnp.arange(idx.shape[0], dtype=jnp.int32) < nnz
    return jnp.where(mask, idx, SENTINEL), val, nnz


def spvec_from_dense(x: Array, cap: int, zero=0):
    present = x != zero
    (idx,) = jnp.nonzero(present, size=cap, fill_value=SENTINEL)
    nnz = jnp.minimum(jnp.sum(present), cap).astype(jnp.int32)
    val = jnp.where(idx != SENTINEL, x[jnp.clip(idx, 0, x.shape[0] - 1)],
                    jnp.asarray(zero, x.dtype))
    return idx.astype(jnp.int32), val, nnz


def spvec_to_dense(idx: Array, val: Array, n: int, zero=0) -> Array:
    out = jnp.full((n,) + val.shape[1:], zero, val.dtype)
    return out.at[idx].set(val, mode="drop")


# --------------------------------------------------------------------------
# SpMV, dense input vector
# --------------------------------------------------------------------------

def spmv_row(a: COO, x: Array, sr: Semiring = ARITHMETIC) -> Array:
    """Row-partitioned SpMV: y = A ⊕.⊗ x via row-segment reduction."""
    sa = a.sort("row")
    xc = x[jnp.clip(sa.col, 0, a.shape[1] - 1)]
    prod = sr.mul(sa.val, xc)
    ids = jnp.where(sa.mask(), sa.row, a.shape[0])
    return segment_reduce(prod, ids, a.shape[0], sr.add, sorted_ids=True)


def spmv_col(a: COO, x: Array, sr: Semiring = ARITHMETIC) -> Array:
    """Col-partitioned SpMV: products scattered into the output accumulator."""
    sa = a.sort("col")
    xc = x[jnp.clip(sa.col, 0, a.shape[1] - 1)]
    prod = sr.mul(sa.val, xc)
    m = a.shape[0]
    vdims = prod.shape[1:]
    out = jnp.full((m,) + vdims, sr.add.identity, prod.dtype)
    rows = jnp.where(sa.mask(), sa.row, SENTINEL)
    if sr.add.tag == "sum":
        pm = sa.mask().reshape((-1,) + (1,) * len(vdims))
        prod = jnp.where(pm, prod, jnp.zeros((), prod.dtype))
        return out.at[rows].add(prod, mode="drop")
    if sr.add.tag == "min":
        return out.at[rows].min(prod, mode="drop")
    if sr.add.tag == "max":
        return out.at[rows].max(prod, mode="drop")
    # generic monoid: fall back to a sort by row (honest extra cost vs 'row')
    ids = jnp.where(sa.mask(), sa.row, m)
    return segment_reduce(prod, ids, m, sr.add, sorted_ids=False)


# --------------------------------------------------------------------------
# SpMSpV, sparse input vector
# --------------------------------------------------------------------------

def _expand_spmspv(a: COO, xi: Array, xv: Array, xnnz: Array, sr: Semiring,
                   prod_cap: int, allow: Array | None = None):
    """Products A(:,k)·x_k for every nonzero x_k. O(df) like the paper.

    ``allow`` (dense bool over the tile's rows, or None) is the output-mask
    pushdown (§4.7): products landing on disallowed rows are dropped HERE,
    before any of the variant merges — the sort never sees them, the SPA
    never scatters them, and ``out_cap`` may be sized to the allowed count.
    """
    sa = a.sort("col")
    k = jnp.where(jnp.arange(xi.shape[0]) < xnnz, xi, SENTINEL)
    start, end = column_range(sa.col, k)
    cnt = jnp.where(k != SENTINEL, end - start, 0)
    off = jnp.cumsum(cnt) - cnt
    nprod = jnp.sum(cnt)
    ok = nprod <= prod_cap
    s = jnp.arange(prod_cap, dtype=jnp.int32)
    t = jnp.searchsorted(off + cnt, s, side="right").astype(jnp.int32)
    tc = jnp.clip(t, 0, xi.shape[0] - 1)
    a_idx = jnp.clip(start[tc] + (s - off[tc]), 0, sa.cap - 1)
    valid = s < nprod
    rr = sa.row[a_idx]
    if allow is not None:
        valid = valid & allow[jnp.clip(rr, 0, a.shape[0] - 1)]
    out_dtype = sr.out_dtype(a.dtype, xv.dtype)
    rows = jnp.where(valid, rr, SENTINEL)
    vals = sr.mul(sa.val[a_idx], xv[tc]).astype(out_dtype)
    vdims = vals.shape[1:]
    vals = jnp.where(valid.reshape((-1,) + (1,) * len(vdims)), vals,
                     jnp.asarray(sr.add.identity, out_dtype))
    return rows, vals, nprod, ok


def spmspv_sort(a: COO, xi, xv, xnnz, sr: Semiring = ARITHMETIC, *,
                prod_cap: int, out_cap: int, allow=None):
    """Sort-merge SpMSpV (heap analogue). Returns ((yi, yv, ynnz), ok)."""
    rows, vals, nprod, ok = _expand_spmspv(a, xi, xv, xnnz, sr, prod_cap,
                                           allow)
    vflat = vals.reshape(prod_cap, -1)
    ops = [rows] + [vflat[:, i] for i in range(vflat.shape[1])]
    sorted_ops = jax.lax.sort(ops, num_keys=1, is_stable=True)
    rows_s = sorted_ops[0]
    vals_s = jnp.stack(sorted_ops[1:], axis=1).reshape(vals.shape) \
        if vflat.shape[1] else vals
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), rows_s[:-1]])
    newgrp = (rows_s != prev) & (rows_s != SENTINEL)
    gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1
    ngrp = jnp.maximum(gid[-1] + 1, 0)
    gid = jnp.where(rows_s != SENTINEL, gid, prod_cap)
    red = segment_reduce(vals_s, gid, out_cap, sr.add, sorted_ids=True)
    first = segment_reduce(jnp.arange(prod_cap, dtype=jnp.int32), gid, out_cap,
                           Monoid(jnp.minimum, 2**31 - 1, "min"), sorted_ids=True)
    valid = jnp.arange(out_cap, dtype=jnp.int32) < ngrp
    yi = jnp.where(valid, rows_s[jnp.clip(first, 0, prod_cap - 1)], SENTINEL)
    vdims = vals.shape[1:]
    yv = jnp.where(valid.reshape((-1,) + (1,) * len(vdims)), red,
                   jnp.asarray(sr.add.identity, red.dtype))
    ok = ok & (ngrp <= out_cap)
    return (yi, yv, jnp.minimum(ngrp, out_cap).astype(jnp.int32)), ok


def spmspv_spa(a: COO, xi, xv, xnnz, sr: Semiring = ARITHMETIC, *,
               prod_cap: int, out_cap: int, allow=None):
    """SPA SpMSpV: dense accumulator of length m, then re-sparsify."""
    rows, vals, nprod, ok = _expand_spmspv(a, xi, xv, xnnz, sr, prod_cap,
                                           allow)
    m = a.shape[0]
    dense = _scatter_monoid(rows, vals, m, sr.add)
    yi, yv, ynnz = spvec_from_dense(dense, out_cap, zero=sr.add.identity)
    cnt = jnp.sum(dense != jnp.asarray(sr.add.identity, dense.dtype))
    return (yi, yv, ynnz), ok & (cnt <= out_cap)


def spmspv_bucket(a: COO, xi, xv, xnnz, sr: Semiring = ARITHMETIC, *,
                  prod_cap: int, out_cap: int, nbuckets: int = 16,
                  allow=None):
    """Propagation-blocking SpMSpV (paper's SpMSpV-Bucket, [25]/[27]).

    Products are partitioned by row-bucket (radix by high bits) and each
    bucket is accumulated in its own bucket-local SPA slice; the bucket pass
    converts random scatter over m rows into nbuckets streaming passes over
    m/nbuckets-wide windows (the TPU analogue keeps each window VMEM-sized).
    """
    rows, vals, nprod, ok = _expand_spmspv(a, xi, xv, xnnz, sr, prod_cap,
                                           allow)
    m = a.shape[0]
    bwidth = -(-m // nbuckets)
    bucket = jnp.where(rows != SENTINEL, rows // bwidth, nbuckets)
    # radix-partition products by bucket id (stable keeps row order within)
    vflat = vals.reshape(prod_cap, -1)
    ops = [bucket.astype(jnp.int32), rows] + \
        [vflat[:, i] for i in range(vflat.shape[1])]
    sorted_ops = jax.lax.sort(ops, num_keys=1, is_stable=True)
    rows_s = sorted_ops[1]
    vals_s = jnp.stack(sorted_ops[2:], axis=1).reshape(vals.shape) \
        if vflat.shape[1] else vals
    # each bucket's SPA is a slice of the length-m accumulator; because the
    # products are already bucket-contiguous the scatter within a bucket
    # touches only its window
    dense = _scatter_monoid(rows_s, vals_s, m, sr.add)
    yi, yv, ynnz = spvec_from_dense(dense, out_cap, zero=sr.add.identity)
    cnt = jnp.sum(dense != jnp.asarray(sr.add.identity, dense.dtype))
    return (yi, yv, ynnz), ok & (cnt <= out_cap)


def _scatter_monoid(rows, vals, m, add: Monoid):
    vdims = vals.shape[1:]
    out = jnp.full((m,) + vdims, add.identity, vals.dtype)
    rr = jnp.where(rows == SENTINEL, jnp.int32(2**31 - 1), rows)
    if add.tag == "sum":
        vm = (rows != SENTINEL).reshape((-1,) + (1,) * len(vdims))
        vals = jnp.where(vm, vals, jnp.zeros((), vals.dtype))
        return out.at[rr].add(vals, mode="drop")
    if add.tag == "min":
        return out.at[rr].min(vals, mode="drop")
    if add.tag == "max":
        return out.at[rr].max(vals, mode="drop")
    ids = jnp.where(rows == SENTINEL, m, rows)
    return segment_reduce(vals, ids, m, add)


SPMSPV_VARIANTS = {
    "sort": spmspv_sort,
    "spa": spmspv_spa,
    "bucket": spmspv_bucket,
}


def spmspv_auto(a: COO, xi, xv, xnnz, sr: Semiring = ARITHMETIC, *,
                prod_cap: int, out_cap: int, allow=None):
    """Fig-3 rule of thumb: sort below ~0.5% vector density, bucket to ~10%,
    SPA above (paper §4.5). Density resolved at runtime via lax.cond."""
    n = a.shape[1]
    density = xnnz.astype(jnp.float32) / max(n, 1)

    def lo(_):
        return spmspv_sort(a, xi, xv, xnnz, sr, prod_cap=prod_cap,
                           out_cap=out_cap, allow=allow)

    def mid(_):
        return spmspv_bucket(a, xi, xv, xnnz, sr, prod_cap=prod_cap,
                             out_cap=out_cap, allow=allow)

    def hi(_):
        return spmspv_spa(a, xi, xv, xnnz, sr, prod_cap=prod_cap,
                          out_cap=out_cap, allow=allow)

    return jax.lax.cond(
        density < 0.005, lo,
        lambda _: jax.lax.cond(density < 0.10, mid, hi, None), None)
