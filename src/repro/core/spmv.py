"""Distributed SpMV / SpMSpV on the 2D grid (paper §3.1, Table 1).

y = A ⊕.⊗ x with A 2D-distributed and x fully distributed (DistVec layout
'col': block j of x is owned collectively by process column j).

SpMV pipeline (the classic 2D algorithm the paper's Table 1 analyses):
  1. all-gather x pieces along the 'row' axis → every device in process
     column j holds the full column block x_j           [O(n/√p) bytes/dev]
  2. local SpMV variant (row- or col-partitioned, §4.2)
  3. reduce partial y along the 'col' axis. For tagged monoids this is a
     reduce-scatter (psum_scatter), yielding y fully distributed in layout
     'row' — no replication, exactly the paper's vector distribution.

SpMSpV keeps the frontier sparse end-to-end (§4.3): sparse pieces are
all-gathered along 'row' (O(nf/√p)), the local SpMSpV variant produces a
sparse partial, and partials merge along 'col' either densely
(psum_scatter) or sparsely (bucketed all-to-all — the fine-grained scheme).

Square grids are required for vectors to round-trip between layouts with a
single transpose permute (CombBLAS restricts most vector ops similarly).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..obs import recorder as _obs
from ..robust import audit as _audit
from .compat import shard_map
from .coo import COO, SENTINEL
from .dist import DistSpMat, DistSpVec, DistVec, specs_of
from .semiring import ARITHMETIC, Monoid, Semiring, segment_reduce
from . import spmv_local as L

Array = jax.Array


def transpose_layout(v: DistVec, *, mesh: Mesh) -> DistVec:
    """Swap piece (i,j) <-> (j,i): converts layout 'row' <-> 'col'."""
    pr, pc = v.grid
    assert pr == pc, "layout transpose needs a square grid"
    q = pr
    perm = [(i * q + j, j * q + i) for i in range(q) for j in range(q)]

    def body(d):
        return jax.lax.ppermute(d, ("row", "col"), perm)

    out = shard_map(body, mesh=mesh, in_specs=P("row", "col", None),
                        out_specs=P("row", "col", None))(v.data)
    new_layout = "row" if v.layout == "col" else "col"
    return DistVec(out, v.n, v.grid, new_layout)


@_obs.timed("spmv")
def spmv(a: DistSpMat, x: DistVec, sr: Semiring = ARITHMETIC, *,
         mesh: Mesh, variant: str = "row") -> DistVec:
    """y = A x. x must be layout 'col'; result is layout 'row'."""
    assert x.layout == "col", "spmv expects a column-layout input vector"
    assert a.shape[1] == x.n or True  # padded blocks make this a soft check
    pr, pc = a.grid
    local_fn = L.spmv_row if variant == "row" else L.spmv_col

    def body(at, xd):
        tile = at.tile()
        xj = jax.lax.all_gather(xd.reshape(-1), "row", tiled=True)  # (nb,)
        y_part = local_fn(tile, xj, sr)                             # (mb,)
        if sr.add.tag == "sum":
            y_piece = jax.lax.psum_scatter(y_part, "col", scatter_dimension=0,
                                           tiled=True)
        else:
            parts = jax.lax.all_gather(y_part, "col")               # (pc, mb)
            red = parts[0]
            for t in range(1, pc):
                red = sr.add.op(red, parts[t])
            j = jax.lax.axis_index("col")
            piece = red.reshape(pc, -1)[j]
            y_piece = piece
        return y_piece[None, None]

    out = shard_map(body, mesh=mesh,
                        in_specs=(specs_of(a), P("row", "col", None)),
                        out_specs=P("row", "col", None))(a, x.data)
    return DistVec(out, a.shape[0], a.grid, "row")


def spmv_iter(a: DistSpMat, x: DistVec, sr: Semiring = ARITHMETIC, *,
              mesh: Mesh, variant: str = "row") -> DistVec:
    """SpMV returning a column-layout vector (ready for the next iteration)."""
    return transpose_layout(spmv(a, x, sr, mesh=mesh, variant=variant),
                            mesh=mesh)


@_obs.timed("spmspv")
def spmspv(a: DistSpMat, x: DistSpVec, sr: Semiring = ARITHMETIC, *,
           mesh: Mesh, variant: str = "sort", merge: str = "sparse",
           prod_cap: int, out_cap: int, mask=None):
    """y = A x with sparse x. Returns (DistSpVec layout 'row', ok[pr,pc]).

    merge='sparse': partial outputs stay sparse; destination pieces receive
    entries via a bucketed all-to-all along 'col' (paper §3.3 fine-grained).
    merge='dense' : partial SPA vectors are psum_scattered (tag 'sum' only).

    ``mask`` (a ``mask.vector_mask`` MaskSpec over a layout-'row' DistVec,
    piece-aligned with y) drops products on non-admissible output rows
    inside the local expansion — BEFORE the variant merges and the 'col'
    exchange (§4.7, direction-optimized BFS's visited pushdown). The mask
    pieces are all-gathered along 'col' (one O(mb) boolean per device,
    the same volume as the output reduction itself).
    """
    assert x.layout == "col"
    # the frontier is about to be all-gathered along 'row' — the wire
    # boundary the audit checksums bracket (robust/audit.guard_exchange)
    x = _audit.guard_exchange("spmspv.comm_x", x)
    pr, pc = a.grid
    local_fn = L.SPMSPV_VARIANTS[variant]
    vb_out = -(-a.shape[0] // (pr * pc))
    mb = a.mb
    mv = mask.vec if mask is not None else None
    if mask is not None:
        if mv is None:
            raise ValueError("spmspv masks are dense-vector masks "
                             "(mask.vector_mask)")
        assert mv.layout == "row" and mv.grid == a.grid \
            and mv.n == a.shape[0], "mask must be piece-aligned with y"

    def body(at, xi, xv, xn, *md):
        tile = at.tile()
        allow = None
        if md:
            member = jnp.asarray(mask.pred(md[0].reshape(-1)))  # (vb,)
            if mask.complement:
                member = ~member
            # process row i's pieces j=0..pc-1 are exactly the tile's row
            # range [i*mb, (i+1)*mb) in j order (layout 'row')
            allow = jax.lax.all_gather(member, "col", tiled=True)  # (mb,)
        # gather the sparse pieces of column block j (localize to block)
        xi_l = xi.reshape(-1)
        xv_l = xv.reshape(-1)
        xn_l = xn.reshape(())
        cap_x = xi_l.shape[0]
        i_in_blk = jax.lax.axis_index("row")
        vb_in = a.nb // pr
        xi_blk = jnp.where(xi_l != SENTINEL, xi_l + i_in_blk * vb_in, SENTINEL)
        gi = jax.lax.all_gather(xi_blk, "row", tiled=True)   # (pr*cap_x,)
        gv = jax.lax.all_gather(xv_l, "row", tiled=True)
        gn = jax.lax.psum(xn_l, "row")
        # compact: local spmspv handles interleaved padding via mask->cnt=0
        # trick: treat gathered arrays as a sparse vector with nnz=total but
        # padding interleaved — _expand masks by index<nnz, so compact first
        order = jnp.argsort(gi == SENTINEL, stable=True)
        gi, gv = gi[order], gv[order]
        (yi, yv, yn), ok = local_fn(tile, gi, gv, gn, sr,
                                    prod_cap=prod_cap, out_cap=out_cap,
                                    allow=allow)
        if merge == "dense" and sr.add.tag == "sum":
            dense = L.spvec_to_dense(yi, yv, mb, zero=0)
            piece = jax.lax.psum_scatter(dense, "col", scatter_dimension=0,
                                         tiled=True)
            # spvec_from_dense clamps nnz to out_cap — detect the overflow
            # before re-sparsifying or truncation would be silent
            ok = ok & (jnp.sum(piece != 0) <= out_cap)
            pi, pv, pn = L.spvec_from_dense(piece, out_cap, zero=0)
            return pi[None, None], pv[None, None], pn[None, None], \
                ok[None, None]
        # ---- sparse merge: bucket partial entries by destination piece ----
        dest = jnp.where(yi != SENTINEL, yi // vb_out, pc)
        cap_d = max(out_cap // pc, 8)
        order2 = jnp.argsort(dest, stable=True)
        d_s = dest[order2]
        seg = jnp.searchsorted(d_s, jnp.arange(pc + 1)).astype(jnp.int32)
        counts = seg[1:] - seg[:-1]
        ok = ok & jnp.all(counts <= cap_d)
        within = jnp.arange(yi.shape[0], dtype=jnp.int32) - \
            seg[jnp.clip(d_s, 0, pc - 1)]
        keep = (d_s < pc) & (within < cap_d)
        # dropped entries write out-of-bounds (mode='drop')
        slot = jnp.where(keep, d_s * cap_d + jnp.minimum(within, cap_d - 1),
                         pc * cap_d)
        bi = jnp.full((pc * cap_d,), SENTINEL, jnp.int32)
        bv = jnp.full((pc * cap_d,), sr.add.identity, yv.dtype)
        yi_s, yv_s = yi[order2], yv[order2]
        bi = bi.at[slot].set(yi_s, mode="drop")
        bv = bv.at[slot].set(yv_s, mode="drop")
        bi = jax.lax.all_to_all(bi.reshape(pc, cap_d), "col", 0, 0) \
            .reshape(pc * cap_d)
        bv = jax.lax.all_to_all(bv.reshape(pc, cap_d), "col", 0, 0) \
            .reshape(pc * cap_d)
        # localize to my piece and merge duplicates from the pc sources
        j = jax.lax.axis_index("col")
        valid = bi != SENTINEL
        li = jnp.where(valid, bi - j * vb_out, SENTINEL)
        d = COO(li, jnp.where(valid, 0, SENTINEL), bv,
                jnp.sum(valid).astype(jnp.int32), (vb_out, 1),
                "none").dedup(sr.add)
        ok = ok & (d.nnz <= out_cap)             # pre-clamp nnz
        merged = d.with_cap(out_cap, sr.add.identity)
        return merged.row[None, None], merged.val[None, None], \
            merged.nnz[None, None], ok[None, None]

    out_specs = (P("row", "col", None), P("row", "col", None),
                 P("row", "col"), P("row", "col"))
    in_specs = (specs_of(a), P("row", "col", None), P("row", "col", None),
                P("row", "col"))
    args = (a, x.idx, x.val, x.nnz)
    if mv is not None:
        in_specs = in_specs + (P("row", "col", None),)
        args = args + (mv.data,)
    with _obs.span("spmspv.execute", variant=variant, merge=merge):
        yi, yv, yn, ok = shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)(*args)
        _obs.sync((yi, yv, yn, ok))
    y = DistSpVec(yi, yv, yn, a.shape[0], a.grid, "row")
    _audit.audit_obj(y, "spmspv.out", min_level=_audit.FULL)
    return y, ok


def transpose_spvec_layout(v: DistSpVec, *, mesh: Mesh) -> DistSpVec:
    pr, pc = v.grid
    assert pr == pc
    q = pr
    perm = [(i * q + j, j * q + i) for i in range(q) for j in range(q)]

    def body(xi, xv, xn):
        f = lambda t: jax.lax.ppermute(t, ("row", "col"), perm)
        return f(xi), f(xv), f(xn)

    yi, yv, yn = shard_map(
        body, mesh=mesh,
        in_specs=(P("row", "col", None), P("row", "col", None),
                  P("row", "col")),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col")))(v.idx, v.val, v.nnz)
    return DistSpVec(yi, yv, yn, v.n, v.grid,
                     "row" if v.layout == "col" else "col")
