"""Capacity-padded local sparse matrix (CombBLAS local SpMat analogue).

JAX/XLA requires static shapes, so a local sparse tile is stored as fixed
*capacity* arrays with an explicit nonzero count:

    COO(row[i32 cap], col[i32 cap], val[cap, *vdims], nnz[i32 scalar])

Canonical padding: entries at positions >= nnz hold ``row = col = SENTINEL``
and ``val = fill`` (the caller's semiring zero). SENTINEL sorts *after* all
real indices, so sorted tiles stay sorted under padding, and JAX scatter's
``mode='drop'`` discards padded writes for free.

Hypersparsity (paper §1, DCSC): tiles from 512-way decompositions have
nnz ≪ n. We therefore never materialize O(n) column pointers; column ranges
are found by binary search over the sorted ``col`` array
(``column_range``) — an O(nnz)-storage DCSC analogue.

Values may be vector-valued (``val.shape == (cap, *vdims)``) to support the
paper's "neighborhood aggregation on vector-valued data" use case.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .semiring import Monoid, Semiring, segment_reduce

Array = jax.Array
SENTINEL = np.int32(2**31 - 1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class COO:
    row: Array
    col: Array
    val: Array
    nnz: Array                       # int32 scalar, actual entry count
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    order: str = dataclasses.field(default="none", metadata=dict(static=True))
    # order in {'none', 'row' (row-major: sorted by (row, col)),
    #           'col' (col-major: sorted by (col, row))}

    @property
    def cap(self) -> int:
        return self.row.shape[0]

    @property
    def vdims(self) -> tuple[int, ...]:
        return tuple(self.val.shape[1:])

    @property
    def dtype(self):
        return self.val.dtype

    def mask(self) -> Array:
        return jnp.arange(self.cap, dtype=jnp.int32) < self.nnz

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @staticmethod
    def empty(shape, cap, dtype=jnp.float32, vdims=(), fill=0, order="row") -> "COO":
        return COO(
            row=jnp.full((cap,), SENTINEL, jnp.int32),
            col=jnp.full((cap,), SENTINEL, jnp.int32),
            val=jnp.full((cap,) + tuple(vdims), fill, dtype),
            nnz=jnp.zeros((), jnp.int32),
            shape=tuple(shape), order=order)

    @staticmethod
    def from_entries(shape, row, col, val, cap=None, nnz=None, fill=0,
                     order="none") -> "COO":
        """Build from (possibly unpadded) entry arrays; pads to ``cap``."""
        row = jnp.asarray(row, jnp.int32)
        col = jnp.asarray(col, jnp.int32)
        val = jnp.asarray(val)
        n = row.shape[0]
        cap = int(cap if cap is not None else n)
        nnz = jnp.asarray(n if nnz is None else nnz, jnp.int32)
        pad = cap - n
        if pad < 0:
            raise ValueError(f"cap {cap} < entries {n}")
        if pad:
            row = jnp.concatenate([row, jnp.full((pad,), SENTINEL, jnp.int32)])
            col = jnp.concatenate([col, jnp.full((pad,), SENTINEL, jnp.int32)])
            val = jnp.concatenate(
                [val, jnp.full((pad,) + tuple(val.shape[1:]), fill, val.dtype)])
        return COO(row, col, val, nnz, tuple(shape), order).canonicalize(fill)

    @staticmethod
    def from_dense(dense: Array, cap: int, zero=0, order="row") -> "COO":
        m, n = dense.shape[:2]
        vdims = dense.shape[2:]
        if vdims:
            present = jnp.any(dense != zero, axis=tuple(range(2, dense.ndim)))
        else:
            present = dense != zero
        r, c = jnp.nonzero(present, size=cap, fill_value=SENTINEL)
        nnz = jnp.minimum(jnp.sum(present), cap).astype(jnp.int32)
        v = dense[jnp.clip(r, 0, m - 1), jnp.clip(c, 0, n - 1)]
        v = jnp.where((r != SENTINEL).reshape((-1,) + (1,) * len(vdims)),
                      v, jnp.asarray(zero, dense.dtype))
        return COO(r.astype(jnp.int32), c.astype(jnp.int32), v, nnz,
                   (int(m), int(n)), order)

    # ------------------------------------------------------------------
    # canonicalization / sorting / dedup
    # ------------------------------------------------------------------
    def canonicalize(self, fill=0) -> "COO":
        """Force padding entries to the canonical (SENTINEL, SENTINEL, fill)."""
        m = self.mask()
        vm = m.reshape((-1,) + (1,) * len(self.vdims))
        return COO(jnp.where(m, self.row, SENTINEL),
                   jnp.where(m, self.col, SENTINEL),
                   jnp.where(vm, self.val, jnp.asarray(fill, self.val.dtype)),
                   self.nnz, self.shape, self.order)

    def sort(self, order: str = "row") -> "COO":
        """Lexicographic sort by (row, col) ['row'] or (col, row) ['col'].

        Packed single-key argsort + one gather (merge engine, DESIGN.md
        §4.4); tiles beyond the packable key space fall back to the two-key
        lax.sort (no int32 overflow for any tile size — the paper's
        32/64-bit split).
        """
        from .merge import sort_packed
        return sort_packed(self, order)

    def dedup(self, add: Monoid, order: str = "row") -> "COO":
        """Merge duplicate (row, col) entries with the add monoid.

        Routed through the merge engine (DESIGN.md §4.4): packed-key argsort
        for untagged tiles, sort-free run reduction when the order tag
        already matches.
        """
        from .merge import dedup as _dedup
        return _dedup(self, add, order)

    def dedup_sorted(self, add: Monoid) -> "COO":
        """Sort-free dedup for tiles already carrying an order tag (§4.3)."""
        from .merge import dedup_sorted as _dedup_sorted
        return _dedup_sorted(self, add)

    # ------------------------------------------------------------------
    # conversions / elementwise
    # ------------------------------------------------------------------
    def to_dense(self, zero=0) -> Array:
        m, n = self.shape
        out = jnp.full((m, n) + self.vdims, zero, self.val.dtype)
        return out.at[self.row, self.col].set(self.val, mode="drop")

    def to_dense_add(self, add: Monoid) -> Array:
        """Dense with duplicate merging (for non-canonical tiles)."""
        m, n = self.shape
        out = jnp.full((m, n) + self.vdims, add.identity, self.val.dtype)
        if add.tag == "sum":
            return out.at[self.row, self.col].add(self.val, mode="drop")
        if add.tag == "min":
            return out.at[self.row, self.col].min(self.val, mode="drop")
        if add.tag == "max":
            return out.at[self.row, self.col].max(self.val, mode="drop")
        d = self.dedup(add)
        return d.to_dense(add.identity)

    def transpose(self) -> "COO":
        # (row, col)-sorted becomes (col, row)-sorted in the new coordinates
        order = {"row": "col", "col": "row"}.get(self.order, "none")
        return COO(self.col, self.row, self.val, self.nnz,
                   (self.shape[1], self.shape[0]), order)

    def apply(self, fn) -> "COO":
        """Elementwise apply on stored values (GraphBLAS apply)."""
        return dataclasses.replace(self, val=jnp.where(
            self.mask().reshape((-1,) + (1,) * len(self.vdims)),
            fn(self.val), self.val))

    def prune(self, keep_fn, fill=0) -> "COO":
        """Drop stored entries where ``keep_fn(val)`` is False (GraphBLAS select)."""
        keep = keep_fn(self.val) & self.mask()
        order = jnp.argsort(~keep, stable=True)  # kept entries first, stable
        row = jnp.where(keep[order], self.row[order], SENTINEL)
        col = jnp.where(keep[order], self.col[order], SENTINEL)
        km = keep[order].reshape((-1,) + (1,) * len(self.vdims))
        val = jnp.where(km, self.val[order], jnp.asarray(fill, self.val.dtype))
        # stable compaction keeps surviving entries in relative order
        return COO(row, col, val, jnp.sum(keep).astype(jnp.int32),
                   self.shape, self.order)

    def reduce(self, axis: int, add: Monoid) -> Array:
        """Row (axis=1) or column (axis=0) reduction to a dense vector."""
        ids = self.row if axis == 1 else self.col
        n_out = self.shape[0] if axis == 1 else self.shape[1]
        ids = jnp.where(self.mask(), ids, n_out)
        return segment_reduce(self.val, ids, n_out, add)

    def scale_rows(self, d: Array, mul=jnp.multiply) -> "COO":
        vm = self.mask().reshape((-1,) + (1,) * len(self.vdims))
        newv = mul(self.val, d[jnp.clip(self.row, 0, self.shape[0] - 1)])
        return dataclasses.replace(self, val=jnp.where(vm, newv, self.val))

    def scale_cols(self, d: Array, mul=jnp.multiply) -> "COO":
        vm = self.mask().reshape((-1,) + (1,) * len(self.vdims))
        newv = mul(self.val, d[jnp.clip(self.col, 0, self.shape[1] - 1)])
        return dataclasses.replace(self, val=jnp.where(vm, newv, self.val))

    def with_cap(self, cap: int, fill=0) -> "COO":
        """Grow (or shrink, keeping first entries) capacity."""
        if cap == self.cap:
            return self
        if cap > self.cap:
            pad = cap - self.cap
            return COO(
                jnp.concatenate([self.row, jnp.full((pad,), SENTINEL, jnp.int32)]),
                jnp.concatenate([self.col, jnp.full((pad,), SENTINEL, jnp.int32)]),
                jnp.concatenate([self.val,
                                 jnp.full((pad,) + self.vdims, fill, self.val.dtype)]),
                self.nnz, self.shape, self.order)
        return COO(self.row[:cap], self.col[:cap], self.val[:cap],
                   jnp.minimum(self.nnz, cap), self.shape, self.order)


def column_range(sorted_cols: Array, k: Array):
    """(start, end) of column ``k`` in a col-major-sorted index array.

    O(log cap) per query, O(nnz) storage — the DCSC analogue (no O(n)
    pointer array). ``k`` may be an array of queries.
    """
    start = jnp.searchsorted(sorted_cols, k, side="left").astype(jnp.int32)
    end = jnp.searchsorted(sorted_cols, k, side="right").astype(jnp.int32)
    return start, end


def row_range(sorted_rows: Array, i: Array):
    start = jnp.searchsorted(sorted_rows, i, side="left").astype(jnp.int32)
    end = jnp.searchsorted(sorted_rows, i, side="right").astype(jnp.int32)
    return start, end


def ewise_union(a: COO, b: COO, add: Monoid, cap: int | None = None) -> COO:
    """C = A ⊕ B (entries present in either; add where both).

    Merge-engine path (DESIGN.md §4.4): both operands row-sort (free under
    the §4.3 invariant) and interleave via the O(n) rank-placement merge —
    no concat-and-sort of the combined stream.
    """
    assert a.shape == b.shape
    from .merge import merge_sorted
    cap = cap or (a.cap + b.cap)
    return merge_sorted(a, b, add).with_cap(cap, add.identity)


def ewise_intersect(a: COO, b: COO, mul, out_cap: int | None = None,
                    zero=0) -> COO:
    """C = A ⊗ B on the intersection pattern (A .* B)."""
    assert a.shape == b.shape
    sa, sb = a.sort("row"), b.sort("row")
    # mark a-entries that also appear in b: binary search b's (row,col)
    out_cap = out_cap or min(a.cap, b.cap)
    # Pair keys are encoded in 32 bits — the CombBLAS "local indices are
    # 32-bit" contract. Local tiles (post 2D/3D decomposition) satisfy this.
    m, n = a.shape
    if (m + 1) * (n + 1) >= 2**31:
        raise ValueError("local tile exceeds 32-bit key space; "
                         "increase the process grid (paper §1, local indices)")
    ka = sa.row * jnp.int32(n + 1) + jnp.minimum(sa.col, n)
    kb = sb.row * jnp.int32(n + 1) + jnp.minimum(sb.col, n)
    ka = jnp.where(sa.mask(), ka, jnp.int32(2**31 - 1))
    kb = jnp.where(sb.mask(), kb, jnp.int32(2**31 - 1))
    pos = jnp.searchsorted(kb, ka)
    posc = jnp.clip(pos, 0, b.cap - 1)
    hit = (kb[posc] == ka) & sa.mask() & (posc < sb.nnz)
    val = mul(sa.val, sb.val[posc])
    out = COO(jnp.where(hit, sa.row, SENTINEL),
              jnp.where(hit, sa.col, SENTINEL),
              jnp.where(hit.reshape((-1,) + (1,) * len(val.shape[1:])),
                        val, jnp.asarray(zero, val.dtype)),
              jnp.sum(hit).astype(jnp.int32), a.shape, "none")
    # compact kept entries to the front; stable, so the row-major order of
    # sa survives and the result keeps the 'row' tag
    order = jnp.argsort(~hit, stable=True)
    out = COO(out.row[order], out.col[order],
              out.val[order], out.nnz, out.shape, "row")
    return out.with_cap(out_cap, zero)
