"""Local (single-tile) SpGEMM under arbitrary semirings (paper §4.1).

CombBLAS 2.0 ships heap-, hash-, and hybrid heap/hash column-by-column
Gustavson SpGEMM. On TPU neither a heap nor a hash table is efficient; the
faithful adaptation (DESIGN.md §4.2) keeps the paper's *structure* — an
O(flops) expansion followed by a merge whose data structure is chosen by
compression ratio — with TPU-native merges:

 - ``spgemm_esc``   expand → lax.sort → segmented reduce. Sort-based merge
                    (the heap's role: wins at LOW compression ratio, where
                    the product list is short relative to the output).
 - ``spgemm_dense`` expand into a dense accumulator tile (the hash table's
                    role: O(1) accumulation, wins at HIGH compression ratio
                    where many products collapse into few outputs) — and it
                    is the MXU-friendly path.
 - ``spgemm_auto``  the paper's hybrid: picks by estimated compression ratio.

All paths are O(flops)-expansion faithful: we never densify the *inputs* in
the ESC path, and the flops estimate (phase 1 of the paper's three-phase
scheme) is computed exactly as nnz-weighted column counts.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .coo import COO, SENTINEL, column_range, row_range
from .semiring import ARITHMETIC, Monoid, Semiring, dense_semiring_matmul

Array = jax.Array


def spgemm_flops(a: COO, b: COO) -> Array:
    """Phase 1 (paper §4.1): exact flops = Σ_t nnz(A(:, B.row[t])).

    Sorted fast path (DESIGN.md §4.2): when B carries the row-major tag the
    same sum is Σ_u nnz(B(A.col[u], :)) over A's entries via binary search on
    B's row pointers — no sort of either operand. Otherwise A is col-sorted
    (free when A already carries the 'col' tag).
    """
    if b.order == "row" and a.order != "col":
        start, end = row_range(b.row, jnp.where(a.mask(), a.col, SENTINEL))
        return jnp.sum(jnp.where(a.mask(), end - start, 0))
    sa = a.sort("col")
    start, end = column_range(sa.col, jnp.where(b.mask(), b.row, SENTINEL))
    return jnp.sum(jnp.where(b.mask(), end - start, 0))


def _expand(a: COO, b: COO, sr: Semiring, prod_cap: int):
    """ESC expansion: one slot per scalar multiply (O(flops) work).

    Returns (rows, cols, vals, nprod, ok). Padding slots hold SENTINEL/zero.

    Two symmetric formulations, selected by the order tags (DESIGN.md §4.2):
      - B row-sorted (the maintained 'row' invariant): walk A's entries and
        binary-search B's row ranges. Sort-free — the fast path.
      - otherwise: col-sort A (free when tagged 'col') and walk B's entries
        against A's column ranges (the seed formulation).
    Both enumerate the identical product multiset, so downstream merge and
    overflow flags are unchanged.
    """
    if b.order == "row" and a.order != "col":
        return _expand_sorted_b(a, b, sr, prod_cap)
    sa = a.sort("col")
    sb = b
    # per-B-nonzero column ranges of A (DCSC-style binary search)
    k = jnp.where(sb.mask(), sb.row, SENTINEL)
    start, end = column_range(sa.col, k)
    cnt = jnp.where(sb.mask(), end - start, 0)
    off = jnp.cumsum(cnt) - cnt                       # exclusive prefix
    nprod = jnp.sum(cnt)
    ok = nprod <= prod_cap

    s = jnp.arange(prod_cap, dtype=jnp.int32)
    # which B-nonzero does product slot s belong to?
    t = jnp.searchsorted(off + cnt, s, side="right").astype(jnp.int32)
    tc = jnp.clip(t, 0, sb.cap - 1)
    a_idx = jnp.clip(start[tc] + (s - off[tc]), 0, sa.cap - 1)
    valid = s < nprod

    out_dtype = sr.out_dtype(a.dtype, b.dtype)
    rows = jnp.where(valid, sa.row[a_idx], SENTINEL)
    cols = jnp.where(valid, sb.col[tc], SENTINEL)
    vals = sr.mul(sa.val[a_idx], sb.val[tc]).astype(out_dtype)
    vdims = vals.shape[1:]
    vals = jnp.where(valid.reshape((-1,) + (1,) * len(vdims)), vals,
                     jnp.asarray(sr.add.identity, out_dtype))
    return rows, cols, vals, nprod, ok


def _expand_sorted_b(a: COO, b: COO, sr: Semiring, prod_cap: int):
    """Sort-free expansion against a row-sorted B (the 'row' invariant path)."""
    # per-A-nonzero row ranges of B (CSR-style binary search on the tag)
    k = jnp.where(a.mask(), a.col, SENTINEL)
    start, end = row_range(b.row, k)
    cnt = jnp.where(a.mask(), end - start, 0)
    off = jnp.cumsum(cnt) - cnt                       # exclusive prefix
    nprod = jnp.sum(cnt)
    ok = nprod <= prod_cap

    s = jnp.arange(prod_cap, dtype=jnp.int32)
    # which A-nonzero does product slot s belong to?
    t = jnp.searchsorted(off + cnt, s, side="right").astype(jnp.int32)
    tc = jnp.clip(t, 0, a.cap - 1)
    b_idx = jnp.clip(start[tc] + (s - off[tc]), 0, b.cap - 1)
    valid = s < nprod

    out_dtype = sr.out_dtype(a.dtype, b.dtype)
    rows = jnp.where(valid, a.row[tc], SENTINEL)
    cols = jnp.where(valid, b.col[b_idx], SENTINEL)
    vals = sr.mul(a.val[tc], b.val[b_idx]).astype(out_dtype)
    vdims = vals.shape[1:]
    vals = jnp.where(valid.reshape((-1,) + (1,) * len(vdims)), vals,
                     jnp.asarray(sr.add.identity, out_dtype))
    return rows, cols, vals, nprod, ok


def spgemm_esc(a: COO, b: COO, sr: Semiring = ARITHMETIC, *,
               prod_cap: int, out_cap: int, order: str = "row",
               mask=None, val_pred=None) -> Tuple[COO, Array]:
    """Expand-Sort-Compress SpGEMM. Returns (C, ok_flag).

    ``mask`` (a ``mask.LocalMask``) drops expanded products before the merge
    (the §4.7 pushdown — ``out_cap`` may then be mask-sized); ``val_pred``
    drops merged entries by output value before the capacity clamp.
    """
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    rows, cols, vals, nprod, ok = _expand(a, b, sr, prod_cap)
    shape = (a.shape[0], b.shape[1])
    if mask is not None:
        from .mask import filter_products
        rows, cols, vals = filter_products(rows, cols, vals, shape, mask,
                                           sr.add.identity)
    prods = COO(rows, cols, vals, jnp.minimum(nprod, prod_cap).astype(jnp.int32),
                shape, "none")
    d = prods.dedup(sr.add, order=order)
    if val_pred is not None:
        from .mask import apply_val_pred
        d = apply_val_pred(d, val_pred, sr.add.identity)
    # check the PRE-clamp nnz: with_cap truncates nnz to out_cap, so
    # testing after the clamp would never detect output overflow
    ok = ok & (d.nnz <= out_cap)
    return d.with_cap(out_cap, sr.add.identity), ok


def spgemm_dense(a: COO, b: COO, sr: Semiring = ARITHMETIC, *,
                 out_cap: int, order: str = "row",
                 mask=None, val_pred=None) -> Tuple[COO, Array]:
    """Dense-accumulator SpGEMM (hash-table analogue; MXU path).

    Densifies inputs into tiles and contracts with the semiring; the
    accumulator is the dense output tile (VMEM-resident on TPU via the
    ``semiring_matmul`` Pallas kernel — see kernels/). Masks apply on the
    dense accumulator (the member matrix is the mask's natural dense view).
    """
    assert a.shape[1] == b.shape[0]
    zero = sr.add.identity
    ad = a.to_dense(zero)
    bd = b.to_dense(zero)
    cd = dense_semiring_matmul(ad, bd, sr)
    if mask is not None:
        from .mask import mask_dense
        member = mask_dense(mask, (a.shape[0], b.shape[1]))
        cd = jnp.where(member, cd, jnp.asarray(zero, cd.dtype))
    if val_pred is not None:
        cd = jnp.where(val_pred(cd), cd, jnp.asarray(zero, cd.dtype))
    c = COO.from_dense(cd, out_cap, zero=zero, order=order)
    ok = jnp.sum(cd != zero) <= out_cap
    return c, ok


def compression_ratio(a: COO, b: COO, sample_out: int | None = None) -> Array:
    """flops / nnz(C) estimate. The paper's hybrid selector statistic.

    nnz(C) is estimated optimistically as min(flops, m*n) when no symbolic
    phase is run; callers with a symbolic pass can supply the true value.
    """
    fl = spgemm_flops(a, b).astype(jnp.float32)
    mn = jnp.float32(a.shape[0] * b.shape[1])
    est_nnz = jnp.minimum(fl, mn)
    return fl / jnp.maximum(est_nnz, 1.0)


def spgemm_auto(a: COO, b: COO, sr: Semiring = ARITHMETIC, *,
                prod_cap: int, out_cap: int, order: str = "row",
                dense_threshold: float = 4.0,
                dense_tile_limit: int = 1 << 22,
                mask=None, val_pred=None) -> Tuple[COO, Array]:
    """Hybrid selector (paper's hash/heap hybrid, adapted).

    Dense-accumulator path when the estimated compression ratio is high and
    the output tile fits the accumulator budget; ESC otherwise. The branch is
    resolved at trace time from static shapes when possible, otherwise via
    lax.cond so both costs stay visible to XLA.
    """
    m, n = a.shape[0], b.shape[1]
    if m * n > dense_tile_limit:
        return spgemm_esc(a, b, sr, prod_cap=prod_cap, out_cap=out_cap,
                          order=order, mask=mask, val_pred=val_pred)
    ratio = compression_ratio(a, b)

    def dense_path(_):
        c, ok = spgemm_dense(a, b, sr, out_cap=out_cap, order=order,
                             mask=mask, val_pred=val_pred)
        return c, ok

    def esc_path(_):
        c, ok = spgemm_esc(a, b, sr, prod_cap=prod_cap, out_cap=out_cap,
                           order=order, mask=mask, val_pred=val_pred)
        return c, ok

    return jax.lax.cond(ratio >= dense_threshold, dense_path, esc_path,
                        operand=None)
