"""Distributed sparse/dense containers (SpParMat / FullyDist[Sp]Vec analogues).

Data model (DESIGN.md §3): a distributed object stores each field as ONE
jax.Array whose leading dims are the process-grid dims, sharded so each
device owns exactly its tile:

  DistSpMat  : row/col/val/nnz with shapes (pr, pc, cap …), P('row','col')
  DistSpMat3D: (L, pr, pc, cap …), P('layer','row','col')
  DistVec    : (pr, pc, vb), P('row','col')  — CombBLAS's superimposed 2D
               vector distribution, NO replication (paper §2.2): piece
               (i, j) holds global block k*vb .. (k+1)*vb where the linear
               piece id k depends on the layout:
                 layout='col': k = j*pr + i  (block j of the matrix column
                                dimension is owned collectively by process
                                column j — what SpMV input needs)
                 layout='row': k = i*pc + j  (block i owned by process row
                                i — what reduce-scattered SpMV output is)
  DistSpVec  : sparse pieces (pr, pc, cap) idx/val/nnz, same piece layout.

Index discipline (paper §1, two index types): global indices are int64 and
live ONLY on the host (numpy) during assembly/extraction; device-resident
indices are tile-local int32.

Load balance (paper §2.3/§6): ``random_permute=True`` at assembly applies a
seeded random row+column permutation — CombBLAS's standard trick, also the
free side effect of ReadGeneralizedTuples.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..obs import recorder as _obs
from ..robust import audit as _audit, faults as _faults
from . import compat
from .coo import COO, SENTINEL

Array = jax.Array


def _ceil(a, b):
    return -(-a // b)


def make_grid(pr: int, pc: int, layers: int = 1,
              devices=None) -> Mesh:
    """Process grid for sparse ops: ('row','col') or ('layer','row','col').

    Axis types (auto) are requested only on jax versions that have them —
    see core/compat.py for the 0.4.x fallback.
    """
    devices = devices if devices is not None else jax.devices()
    n = layers * pr * pc
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    if layers == 1:
        return compat.make_mesh((pr, pc), ("row", "col"), devices=devices[:n])
    return compat.make_mesh((layers, pr, pc), ("layer", "row", "col"),
                            devices=devices[:n])


# --------------------------------------------------------------------------
# 2D distributed sparse matrix
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistSpMat:
    """2D-distributed sparse matrix on a (pr, pc) grid.

    Tile (i, j) covers global rows [i*mb, (i+1)*mb) × cols [j*nb, (j+1)*nb)
    with mb = vbm*pc and nb = vbn*pr (padded so the superimposed vector
    pieces align — see DistVec).
    """

    row: Array   # (pr, pc, cap) int32, tile-local row index
    col: Array   # (pr, pc, cap) int32, tile-local col index
    val: Array   # (pr, pc, cap, *vdims)
    nnz: Array   # (pr, pc) int32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    grid: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    # per-tile entry order, same vocabulary as COO.order. 'row' is the
    # maintained invariant: assembly sorts tiles row-major and every core op
    # either preserves it or re-establishes it via dedup (DESIGN.md §4.3),
    # so local kernels hit their sort-free fast paths.
    order: str = dataclasses.field(default="none", metadata=dict(static=True))

    @property
    def pr(self):
        return self.grid[0]

    @property
    def pc(self):
        return self.grid[1]

    @property
    def cap(self):
        return self.row.shape[-1]

    @property
    def mb(self):
        return _ceil(self.shape[0], self.pr * self.pc) * self.pc

    @property
    def nb(self):
        return _ceil(self.shape[1], self.pr * self.pc) * self.pr

    @property
    def total_nnz(self):
        return jnp.sum(self.nnz)

    def tile(self, squeeze3=True) -> COO:
        """Local COO view — call inside shard_map only."""
        r = self.row.reshape(self.cap)
        c = self.col.reshape(self.cap)
        v = self.val.reshape((self.cap,) + self.val.shape[3:])
        n = self.nnz.reshape(())
        return COO(r, c, v, n, (self.mb, self.nb), self.order)

    # ---------------- host-side assembly / extraction ----------------
    @staticmethod
    @_obs.timed("dist.assemble")
    def from_global_coo(shape, rows, cols, vals, grid, *, mesh: Mesh = None,
                        cap: int | None = None, pad: float = 1.25,
                        random_permute: bool = False, seed: int = 0,
                        vdims=(), order: str = "row"):
        """Assemble from global int64 COO (host-side numpy).

        ``order`` picks the per-tile entry sort — ``'row'`` (the maintained
        invariant) or ``'col'`` (so :meth:`regrid` can preserve a
        col-ordered matrix's tag through re-assembly).
        """
        M, N = shape
        pr, pc = grid
        if order not in ("row", "col"):
            raise ValueError(f"order must be 'row' or 'col', got {order!r}")
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        if random_permute:
            rng = np.random.default_rng(seed)
            rp = rng.permutation(M).astype(np.int64)
            cp = rp if M == N else rng.permutation(N).astype(np.int64)
            rows, cols = rp[rows], cp[cols]
        mb = _ceil(M, pr * pc) * pc
        nb = _ceil(N, pr * pc) * pr
        ti, tj = rows // mb, cols // nb
        lr = (rows % mb).astype(np.int32)
        lc = (cols % nb).astype(np.int32)
        tid = ti * pc + tj
        within_keys = (lc, lr) if order == "row" else (lr, lc)
        perm = np.lexsort(within_keys + (tid,))
        tid, lr, lc, vals_s = tid[perm], lr[perm], lc[perm], vals[perm]
        counts = np.bincount(tid, minlength=pr * pc)
        if cap is None:
            cap = max(8, int(math.ceil(counts.max() * pad / 8) * 8)) \
                if len(rows) else 8
        if counts.max() > cap:
            raise ValueError(f"tile overflow: max nnz {counts.max()} > cap {cap}")
        starts = np.zeros(pr * pc, np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        R = np.full((pr * pc, cap), SENTINEL, np.int32)
        Cc = np.full((pr * pc, cap), SENTINEL, np.int32)
        V = np.zeros((pr * pc, cap) + tuple(vdims), vals.dtype)
        within = np.arange(len(rows)) - starts[tid]
        R[tid, within] = lr
        Cc[tid, within] = lc
        V[tid, within] = vals_s
        out = DistSpMat(
            row=jnp.asarray(R.reshape(pr, pc, cap)),
            col=jnp.asarray(Cc.reshape(pr, pc, cap)),
            val=jnp.asarray(V.reshape((pr, pc, cap) + tuple(vdims))),
            nnz=jnp.asarray(counts.reshape(pr, pc).astype(np.int32)),
            shape=(int(M), int(N)), grid=(pr, pc),
            # the lexsort above sorted each tile by the requested key
            order=order)
        out = _faults.corrupt_spmat("dist.assemble", out)
        _audit.audit_obj(out, "dist.assemble", min_level=_audit.FULL)
        if mesh is not None:
            out = shard_put(out, mesh)
        return out

    def to_global_coo(self):
        """Gather to host as (rows, cols, vals) in global int64 coords."""
        pr, pc, cap = self.pr, self.pc, self.cap
        R = np.asarray(self.row).reshape(pr, pc, cap)
        C = np.asarray(self.col).reshape(pr, pc, cap)
        V = np.asarray(self.val).reshape((pr, pc, cap) + self.val.shape[3:])
        Nz = np.asarray(self.nnz).reshape(pr, pc)
        rows, cols, vals = [], [], []
        for i in range(pr):
            for j in range(pc):
                k = int(Nz[i, j])
                rows.append(R[i, j, :k].astype(np.int64) + i * self.mb)
                cols.append(C[i, j, :k].astype(np.int64) + j * self.nb)
                vals.append(V[i, j, :k])
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))

    @_obs.timed("dist.regrid")
    def regrid(self, grid, *, mesh: Mesh = None, cap: int | None = None,
               pad: float = 1.25) -> "DistSpMat":
        """Re-distribute onto a new process grid (elastic shrink/grow).

        Round-trips through global COO and the normal assembly path, so
        entry values are bit-identical, the ``order`` tag is preserved
        ('none' tightens to 'row' — assembly sorts anyway), and the tile
        capacity is re-planned for the new tiling unless ``cap`` is given.
        This is the topology-recovery primitive: a 4×4 grid that lost
        devices regrids to 2×2 and every downstream op just works.
        """
        rows, cols, vals = self.to_global_coo()
        tag = self.order if self.order in ("row", "col") else "row"
        return DistSpMat.from_global_coo(
            self.shape, rows, cols, vals, tuple(grid), mesh=mesh, cap=cap,
            pad=pad, vdims=self.val.shape[3:], order=tag)

    def to_dense(self, zero=0.0) -> np.ndarray:
        r, c, v = self.to_global_coo()
        out = np.full(self.shape + self.val.shape[3:], zero,
                      np.asarray(self.val).dtype)
        out[r, c] = v
        return out


# --------------------------------------------------------------------------
# 3D (communication-avoiding) distributed sparse matrix
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistSpMat3D:
    """Sparse matrix on a (L, q, q) grid (paper §3.2, Fig 1).

    dist='acol': input-A style — columns sliced into L outer slabs; layer l
                 holds slab l as a 2D (q×q) matrix.
    dist='brow': input-B style — rows sliced into L outer slabs.
    dist='csub': output style (Fig 2) — within each column block j, columns
                 are sub-sliced into L pieces; layer l holds sub-piece l.
    """

    row: Array   # (L, q, q, cap) int32
    col: Array
    val: Array
    nnz: Array   # (L, q, q)
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    grid: tuple[int, int, int] = dataclasses.field(metadata=dict(static=True))
    dist: str = dataclasses.field(metadata=dict(static=True))
    order: str = dataclasses.field(default="none", metadata=dict(static=True))

    @property
    def L(self):
        return self.grid[0]

    @property
    def q(self):
        return self.grid[1]

    @property
    def cap(self):
        return self.row.shape[-1]

    def block_sizes(self):
        """(tile_rows, tile_cols) of each local tile.

        Every dimension is padded to a multiple of L*q*q so that (a) the
        contraction dims of acol-A and brow-B tiles agree and (b) partial-C
        column blocks subdivide exactly L ways for the inter-layer all-to-all.
        """
        M, N = self.shape
        L, q = self.L, self.q
        if self.dist == "acol":
            return _pad_to(M, L * q * q) // q, _pad_to(N, L * q * q) // (L * q)
        if self.dist == "brow":
            return _pad_to(M, L * q * q) // (L * q), _pad_to(N, L * q * q) // q
        if self.dist == "csub":
            return _pad_to(M, L * q * q) // q, _pad_to(N, L * q * q) // (L * q)
        raise ValueError(self.dist)

    def tile(self) -> COO:
        cap = self.cap
        tr, tc = self.block_sizes()
        return COO(self.row.reshape(cap), self.col.reshape(cap),
                   self.val.reshape((cap,) + self.val.shape[4:]),
                   self.nnz.reshape(()), (tr, tc), self.order)

    def _global_offsets(self, l, i, j):
        tr, tc = self.block_sizes()
        M, N = self.shape
        L, q = self.L, self.q
        if self.dist == "acol":
            return i * tr, l * (tc * q) + j * tc
        if self.dist == "brow":
            return l * (tr * q) + i * tr, j * tc
        if self.dist == "csub":
            return i * tr, j * (tc * L) + l * tc
        raise ValueError(self.dist)

    @staticmethod
    @_obs.timed("dist.assemble3d")
    def from_global_coo(shape, rows, cols, vals, grid, dist, *,
                        mesh: Mesh = None, cap=None, pad=1.25,
                        random_permute=False, seed=0):
        L, q, _ = grid
        M, N = shape
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        if random_permute:
            rng = np.random.default_rng(seed)
            rp = rng.permutation(M).astype(np.int64)
            cp = rp if M == N else rng.permutation(N).astype(np.int64)
            rows, cols = rp[rows], cp[cols]
        proto = DistSpMat3D(None, None, None, None, (int(M), int(N)),
                            (L, q, q), dist)
        tr, tc = proto.block_sizes()
        if dist == "acol":
            l = cols // (tc * q)
            i, j = rows // tr, (cols % (tc * q)) // tc
            lr, lc = rows % tr, cols % tc
        elif dist == "brow":
            l = rows // (tr * q)
            i, j = (rows % (tr * q)) // tr, cols // tc
            lr, lc = rows % tr, cols % tc
        else:  # csub
            jblk = cols // (tc * L)
            rem = cols % (tc * L)
            l, j = rem // tc, jblk
            i = rows // tr
            lr, lc = rows % tr, rem % tc
        tid = (l * q + i) * q + j
        order = np.lexsort((lc.astype(np.int32), lr.astype(np.int32), tid))
        tid = tid[order]
        lr, lc, vals_s = lr[order].astype(np.int32), lc[order].astype(np.int32), vals[order]
        ntile = L * q * q
        counts = np.bincount(tid, minlength=ntile)
        if cap is None:
            cap = max(8, int(math.ceil((counts.max() if len(rows) else 1)
                                       * pad / 8) * 8))
        if len(rows) and counts.max() > cap:
            raise ValueError(f"tile overflow: {counts.max()} > {cap}")
        starts = np.zeros(ntile, np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        R = np.full((ntile, cap), SENTINEL, np.int32)
        Cc = np.full((ntile, cap), SENTINEL, np.int32)
        V = np.zeros((ntile, cap), vals.dtype)
        within = np.arange(len(rows)) - starts[tid]
        R[tid, within] = lr
        Cc[tid, within] = lc
        V[tid, within] = vals_s
        out = DistSpMat3D(
            row=jnp.asarray(R.reshape(L, q, q, cap)),
            col=jnp.asarray(Cc.reshape(L, q, q, cap)),
            val=jnp.asarray(V.reshape(L, q, q, cap)),
            nnz=jnp.asarray(counts.reshape(L, q, q).astype(np.int32)),
            shape=(int(M), int(N)), grid=(L, q, q), dist=dist,
            order="row")  # lexsort above is (lr, lc) within tile
        if mesh is not None:
            out = shard_put(out, mesh)
        return out

    def to_global_coo(self):
        L, q, cap = self.L, self.q, self.cap
        R = np.asarray(self.row)
        C = np.asarray(self.col)
        V = np.asarray(self.val)
        Nz = np.asarray(self.nnz)
        rows, cols, vals = [], [], []
        for l in range(L):
            for i in range(q):
                for j in range(q):
                    k = int(Nz[l, i, j])
                    ro, co = self._global_offsets(l, i, j)
                    rows.append(R[l, i, j, :k].astype(np.int64) + ro)
                    cols.append(C[l, i, j, :k].astype(np.int64) + co)
                    vals.append(V[l, i, j, :k])
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))

    @_obs.timed("dist.regrid3d")
    def regrid(self, grid, *, mesh: Mesh = None, cap: int | None = None,
               pad: float = 1.25, dist: str | None = None) -> "DistSpMat3D":
        """Re-distribute onto a new (L, q, q) grid (elastic shrink/grow).

        The 3D analogue of :meth:`DistSpMat.regrid` — a replication-layer
        loss regrids (4, q, q) → (2, q, q) through global COO and the
        normal assembly path. ``dist`` defaults to the current
        distribution style; capacity is re-planned unless ``cap`` is given.
        """
        rows, cols, vals = self.to_global_coo()
        return DistSpMat3D.from_global_coo(
            self.shape, rows, cols, vals, tuple(grid), dist or self.dist,
            mesh=mesh, cap=cap, pad=pad)

    def to_dense(self, zero=0.0) -> np.ndarray:
        r, c, v = self.to_global_coo()
        out = np.full(self.shape, zero, np.asarray(self.val).dtype)
        out[r, c] = v
        return out


def _pad_to(n, mult):
    return _ceil(n, mult) * mult


# --------------------------------------------------------------------------
# distributed dense / sparse vectors
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistVec:
    """Fully distributed dense vector, no replication (paper §2.2)."""

    data: Array  # (pr, pc, vb)
    n: int = dataclasses.field(metadata=dict(static=True))
    grid: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    layout: str = dataclasses.field(default="col", metadata=dict(static=True))

    @property
    def vb(self):
        return self.data.shape[2]

    def piece_id(self, i, j):
        return j * self.grid[0] + i if self.layout == "col" \
            else i * self.grid[1] + j

    @staticmethod
    def from_global(x, grid, layout="col", mesh: Mesh = None):
        pr, pc = grid
        x = np.asarray(x)
        n = x.shape[0]
        vb = _ceil(n, pr * pc)
        xp = np.zeros((pr * pc * vb,) + x.shape[1:], x.dtype)
        xp[:n] = x
        pieces = xp.reshape((pr * pc, vb) + x.shape[1:])
        out = np.empty((pr, pc, vb) + x.shape[1:], x.dtype)
        for i in range(pr):
            for j in range(pc):
                k = j * pr + i if layout == "col" else i * pc + j
                out[i, j] = pieces[k]
        v = DistVec(jnp.asarray(out), int(n), (pr, pc), layout)
        if mesh is not None:
            v = shard_put(v, mesh)
        return v

    def to_global(self) -> np.ndarray:
        pr, pc = self.grid
        d = np.asarray(self.data)
        vb = self.vb
        xp = np.empty((pr * pc, vb) + d.shape[3:], d.dtype)
        for i in range(pr):
            for j in range(pc):
                k = j * pr + i if self.layout == "col" else i * pc + j
                xp[k] = d[i, j]
        return xp.reshape((pr * pc * vb,) + d.shape[3:])[:self.n]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistSpVec:
    """Fully distributed sparse vector (FullyDistSpVec)."""

    idx: Array   # (pr, pc, cap) int32, piece-local indices
    val: Array   # (pr, pc, cap)
    nnz: Array   # (pr, pc) int32
    n: int = dataclasses.field(metadata=dict(static=True))
    grid: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    layout: str = dataclasses.field(default="col", metadata=dict(static=True))

    @property
    def cap(self):
        return self.idx.shape[-1]

    @property
    def vb(self):
        pr, pc = self.grid
        return _ceil(self.n, pr * pc)

    @staticmethod
    def from_global(idx, val, n, grid, cap=None, layout="col",
                    mesh: Mesh = None, pad=1.5):
        pr, pc = grid
        idx = np.asarray(idx, np.int64)
        val = np.asarray(val)
        vb = _ceil(n, pr * pc)
        piece = idx // vb
        local = (idx % vb).astype(np.int32)
        counts = np.bincount(piece, minlength=pr * pc)
        if cap is None:
            cap = max(8, int(math.ceil((counts.max() if len(idx) else 1)
                                       * pad / 8) * 8))
        if len(idx) and counts.max() > cap:
            raise ValueError("piece overflow")
        order = np.lexsort((local, piece))
        piece, local, val_s = piece[order], local[order], val[order]
        starts = np.zeros(pr * pc, np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        I = np.full((pr * pc, cap), SENTINEL, np.int32)
        V = np.zeros((pr * pc, cap), val.dtype)
        within = np.arange(len(idx)) - starts[piece]
        I[piece, within] = local
        V[piece, within] = val_s
        Ii = np.empty((pr, pc, cap), np.int32)
        Vv = np.empty((pr, pc, cap), val.dtype)
        Nz = np.empty((pr, pc), np.int32)
        for i in range(pr):
            for j in range(pc):
                k = j * pr + i if layout == "col" else i * pc + j
                Ii[i, j], Vv[i, j], Nz[i, j] = I[k], V[k], counts[k]
        v = DistSpVec(jnp.asarray(Ii), jnp.asarray(Vv), jnp.asarray(Nz),
                      int(n), (pr, pc), layout)
        if mesh is not None:
            v = shard_put(v, mesh)
        return v

    def to_global(self):
        pr, pc = self.grid
        I = np.asarray(self.idx)
        V = np.asarray(self.val)
        Nz = np.asarray(self.nnz)
        idxs, vals = [], []
        for i in range(pr):
            for j in range(pc):
                k = j * pr + i if self.layout == "col" else i * pc + j
                c = int(Nz[i, j])
                idxs.append(I[i, j, :c].astype(np.int64) + k * self.vb)
                vals.append(V[i, j, :c])
        idx = np.concatenate(idxs)
        val = np.concatenate(vals)
        keep = idx < self.n
        return idx[keep], val[keep]

    def to_global_dense(self, zero=0.0):
        idx, val = self.to_global()
        out = np.full((self.n,), zero, np.asarray(self.val).dtype)
        out[idx] = val
        return out


# --------------------------------------------------------------------------
# sharding helpers
# --------------------------------------------------------------------------

_SPEC2 = {"DistSpMat": dict(row=P("row", "col", None),
                            col=P("row", "col", None),
                            val=P("row", "col", None),
                            nnz=P("row", "col")),
          "DistSpMat3D": dict(row=P("layer", "row", "col", None),
                              col=P("layer", "row", "col", None),
                              val=P("layer", "row", "col", None),
                              nnz=P("layer", "row", "col")),
          "DistVec": dict(data=P("row", "col", None)),
          "DistSpVec": dict(idx=P("row", "col", None),
                            val=P("row", "col", None),
                            nnz=P("row", "col"))}


def specs_of(obj):
    """Matching pytree of PartitionSpecs for a distributed object."""
    table = _SPEC2[type(obj).__name__]

    def fix(name, arr):
        spec = table[name]
        extra = arr.ndim - len(spec)
        return P(*(tuple(spec) + (None,) * extra))

    kw = {f.name: fix(f.name, getattr(obj, f.name))
          for f in dataclasses.fields(obj)
          if f.name in table}
    rest = {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)
            if f.name not in table}
    return dataclasses.replace(obj, **{**kw, **rest})


def shard_put(obj, mesh: Mesh):
    """Place a distributed object onto its mesh with the canonical sharding."""
    spec_tree = specs_of(obj)
    table = _SPEC2[type(obj).__name__]
    kw = {}
    for f in dataclasses.fields(obj):
        if f.name in table:
            kw[f.name] = jax.device_put(
                getattr(obj, f.name),
                NamedSharding(mesh, getattr(spec_tree, f.name)))
    return dataclasses.replace(obj, **kw)


# --------------------------------------------------------------------------
# mesh-independent sparse checkpoints (elastic topology recovery)
# --------------------------------------------------------------------------

@_obs.timed("dist.ckpt_save")
def save_spmat(ckpt_dir: str, step: int, m, *, keep: int = 3) -> str:
    """Checkpoint a DistSpMat/DistSpMat3D through the CRC-manifest path.

    The matrix is saved as *global COO* plus metadata — not as grid-shaped
    tiles — so :func:`restore_spmat` can re-assemble it onto ANY grid
    (including a smaller one after device loss). Rides the atomic-rename +
    per-leaf CRC32 + fallback-to-previous-step machinery of
    ``train/checkpoint.py`` unchanged.
    """
    from ..train.checkpoint import save_checkpoint   # lazy: train is heavy
    rows, cols, vals = m.to_global_coo()
    tree = {"rows": rows, "cols": cols, "vals": vals,
            "shape": np.asarray(m.shape, np.int64),
            "order": np.frombuffer(m.order.encode(), np.uint8),
            "kind": np.frombuffer(type(m).__name__.encode(), np.uint8)}
    if isinstance(m, DistSpMat3D):
        tree["dist"] = np.frombuffer(m.dist.encode(), np.uint8)
    return save_checkpoint(ckpt_dir, step, tree, keep=keep)


@_obs.timed("dist.ckpt_restore")
def restore_spmat(ckpt_dir: str, grid, *, mesh: Mesh = None,
                  step: int | None = None, cap: int | None = None,
                  pad: float = 1.25, dist: str | None = None):
    """Restore a sparse checkpoint onto ``grid`` (any shape); returns
    ``(matrix, step)``.

    The target ``grid`` chooses the container family: a 2-tuple rebuilds a
    :class:`DistSpMat`, a 3-tuple a :class:`DistSpMat3D` (``dist`` defaults
    to the saved distribution style). Capacity is re-planned for the target
    tiling and the saved ``order`` tag is preserved — the regrid-on-resume
    half of elastic recovery.
    """
    from ..train.checkpoint import restore_flat      # lazy: train is heavy
    state, step = restore_flat(ckpt_dir, step)
    shape = tuple(int(x) for x in np.asarray(state["shape"]))
    saved_order = bytes(np.asarray(state["order"])).decode()
    tag = saved_order if saved_order in ("row", "col") else "row"
    grid = tuple(int(g) for g in grid)
    if len(grid) == 3:
        d = dist or bytes(np.asarray(state["dist"])).decode()
        m = DistSpMat3D.from_global_coo(
            shape, state["rows"], state["cols"], state["vals"], grid, d,
            mesh=mesh, cap=cap, pad=pad)
    else:
        vals = np.asarray(state["vals"])
        m = DistSpMat.from_global_coo(
            shape, state["rows"], state["cols"], vals, grid, mesh=mesh,
            cap=cap, pad=pad, vdims=vals.shape[1:], order=tag)
    return m, step
