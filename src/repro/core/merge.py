"""Sort-free merge engine (CombBLAS 2.0 §5 multiway merge, DESIGN.md §4.4).

Every SpGEMM and element-wise path in this repo ends in a merge of
(row, col, val) streams. The seed implementation paid a full two-key
``lax.sort`` that dragged every value column through the comparator — even
when the inputs were already sorted (the ``order='row'`` invariant, §4.3).
This module replaces that with three graded primitives:

  1. **Packed-key dedup** (``dedup``): encode (row, col) into ONE integer
     key — int32 when the tile fits the 32-bit key space (the CombBLAS
     "local indices are 32-bit" contract), int64 above it — then a single
     key argsort + one gather of the values. The sort comparator touches
     2 operands (key, iota) instead of 2 keys + every value column, and the
     unique (row, col) pairs are decoded straight from the merged keys
     (no index gathers).
  2. **Sorted fast path** (``dedup_sorted``): inputs carrying an order tag
     skip the argsort entirely — run-boundary detection + segmented
     reduction only. O(n) instead of O(n log n).
  3. **Merge path** (``merge_sorted`` / ``merge_tree``): two already-sorted
     streams interleave in O(n) via ``searchsorted`` rank placement (the
     paper's binary merge scheme): entry i of A lands at
     ``i + |{b < a_i}|``, entry j of B at ``j + |{a <= b_j}|`` — a bijection
     onto [0, |A|+|B|), computed with two binary searches and two scatters,
     never a sort. ``merge_tree`` folds q SUMMA stage buffers pairwise.

The seed two-key implementation survives as ``sort_two_key`` /
``dedup_legacy``: the fallback when keys cannot pack (huge tile without
x64) and the benchmark baseline for the engine's speedup claims.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from ..obs import recorder as _obs
from ..robust import faults as _faults
from .coo import SENTINEL
from .semiring import Monoid, segment_reduce

Array = jax.Array

# Degradation switch (robust/recover.py 'legacy-dedup' rung): when set, the
# packed-key engine's entry points route to the seed two-key implementations.
_FORCE_LEGACY = False


def force_legacy_dedup(on: bool):
    """Route ``dedup``/``sort_packed`` to the seed two-key paths."""
    global _FORCE_LEGACY
    _FORCE_LEGACY = bool(on)


def legacy_dedup_forced() -> bool:
    return _FORCE_LEGACY

# Cap on the per-stage compaction windows kv_from_products unrolls: bounds
# XLA program size when prod_cap >> stage_cap (high-compression multiplies)
# at the cost of coarser slack skipping.
MAX_WINDOWS = 8


# --------------------------------------------------------------------------
# key packing
# --------------------------------------------------------------------------

def key_dtype(shape) -> jnp.dtype | None:
    """Narrowest integer dtype that can pack (row, col) for ``shape``.

    int32 while (m+1)·(n+1) fits 31 bits (so the max live key stays below
    the all-ones padding key); int64 above that when x64 is enabled; None
    when packing is impossible (callers fall back to the two-key sort).
    """
    m, n = shape
    if (m + 1) * (n + 1) < 2**31:
        return jnp.int32
    if jax.config.jax_enable_x64:
        return jnp.int64
    return None


def pack_keys(row: Array, col: Array, shape, order: str = "row"):
    """(row, col) -> single sortable key; SENTINEL coords -> dtype max.

    'row' keys sort row-major, 'col' keys col-major. Returns None when the
    tile exceeds the packable key space.
    """
    kd = key_dtype(shape)
    if kd is None:
        return None
    m, n = shape
    kmax = jnp.asarray(jnp.iinfo(kd).max, kd)
    live = (row != SENTINEL) & (col != SENTINEL)
    if order == "row":
        k = row.astype(kd) * (n + 1) + col.astype(kd)
    else:
        k = col.astype(kd) * (m + 1) + row.astype(kd)
    return jnp.where(live, k, kmax)


def _unpack(keys: Array, shape, order: str):
    """Inverse of pack_keys for live keys (padding handled by callers)."""
    m, n = shape
    base = (n + 1) if order == "row" else (m + 1)
    hi = (keys // base).astype(jnp.int32)
    lo = (keys % base).astype(jnp.int32)
    return (hi, lo) if order == "row" else (lo, hi)


# --------------------------------------------------------------------------
# legacy two-key sort/dedup (seed implementation: fallback + benchmark base)
# --------------------------------------------------------------------------

def sort_two_key(c, order: str = "row"):
    """Seed COO.sort: two-key lax.sort dragging every value column."""
    from .coo import COO
    if c.order == order:
        return c
    k1, k2 = (c.row, c.col) if order == "row" else (c.col, c.row)
    vflat = c.val.reshape(c.cap, -1)
    ops = [k1, k2] + [vflat[:, i] for i in range(vflat.shape[1])]
    out = jax.lax.sort(ops, num_keys=2, is_stable=True)
    val = jnp.stack(out[2:], axis=1).reshape(c.val.shape) \
        if vflat.shape[1] else c.val
    row, col = (out[0], out[1]) if order == "row" else (out[1], out[0])
    return COO(row, col, val, c.nnz, c.shape, order)


def dedup_legacy(c, add: Monoid, order: str = "row"):
    """Seed COO.dedup: two-key sort + two segment reductions."""
    from .coo import COO
    s = sort_two_key(c, order)
    k1, k2 = (s.row, s.col) if order == "row" else (s.col, s.row)
    prev1 = jnp.concatenate([jnp.full((1,), -1, jnp.int32), k1[:-1]])
    prev2 = jnp.concatenate([jnp.full((1,), -1, jnp.int32), k2[:-1]])
    live = s.mask() & (s.row != SENTINEL) & (s.col != SENTINEL)
    newgrp = ((k1 != prev1) | (k2 != prev2)) & live
    gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1
    ngrp = jnp.maximum(jnp.max(jnp.where(live, gid, -1)) + 1, 0)
    gid = jnp.where(live, gid, c.cap)
    vals = segment_reduce(s.val, gid, c.cap, add, sorted_ids=True)
    first_of_grp = segment_reduce(jnp.arange(c.cap, dtype=jnp.int32),
                                  gid, c.cap,
                                  Monoid(jnp.minimum, 2**31 - 1, "min"),
                                  sorted_ids=True)
    idx = jnp.clip(first_of_grp, 0, c.cap - 1)
    valid = jnp.arange(c.cap, dtype=jnp.int32) < ngrp
    row = jnp.where(valid, s.row[idx], SENTINEL)
    col = jnp.where(valid, s.col[idx], SENTINEL)
    vm = valid.reshape((-1,) + (1,) * len(c.vdims))
    val = jnp.where(vm, vals, jnp.asarray(add.identity, vals.dtype))
    return COO(row, col, val, ngrp.astype(jnp.int32), c.shape, order)


# --------------------------------------------------------------------------
# packed-key engine
# --------------------------------------------------------------------------

def _sort_kv(keys: Array, vals: Array):
    """Sort (key, val) by key. Scalar values ride the comparator network as
    a payload (single-key unstable sort: ~1.9x cheaper than the legacy
    two-key stable sort); vector values take one iota payload + one gather.
    Unstable is sound here: dedup consumers combine equal-key runs with a
    commutative monoid (the Monoid contract), so run-internal order is
    unobservable.
    """
    if vals.ndim == 1:
        ks, vs = jax.lax.sort([keys, vals], num_keys=1, is_stable=False)
        return ks, vs
    iota = jnp.arange(keys.shape[0], dtype=jnp.int32)
    ks, perm = jax.lax.sort([keys, iota], num_keys=1, is_stable=False)
    return ks, vals[perm]


def _run_bounds(keys: Array, nnz: Array):
    """(gid, ngrp) for an ascending key stream with dtype-max padding."""
    cap = keys.shape[0]
    kmax = jnp.iinfo(keys.dtype).max
    live = (jnp.arange(cap, dtype=jnp.int32) < nnz) & (keys != kmax)
    prev = jnp.concatenate([jnp.full((1,), -1, keys.dtype), keys[:-1]])
    newgrp = (keys != prev) & live
    cs = jnp.cumsum(newgrp.astype(jnp.int32))
    gid = jnp.where(live, cs - 1, cap)                       # pad -> drop
    return gid, cs[-1]                                       # ngrp = total runs


def _reduce_runs(keys: Array, vals: Array, nnz: Array, shape, add: Monoid,
                 order: str):
    """Fuse equal-key runs of an ascending (key, val) stream into a COO.

    ``keys`` must be sorted with padding (dtype max) at the end; the first
    ``nnz`` slots are the live entries. One boundary scan + one segmented
    reduction; unique (row, col) decode straight from the keys.
    """
    from .coo import COO
    cap = keys.shape[0]
    kmax = jnp.iinfo(keys.dtype).max
    gid, ngrp = _run_bounds(keys, nnz)
    out_vals = segment_reduce(vals, gid, cap, add, sorted_ids=True)
    # group g's key via scatter-min (all keys within a run are equal)
    ukey = jnp.full((cap,), kmax, keys.dtype).at[gid].min(keys, mode="drop")
    valid = jnp.arange(cap, dtype=jnp.int32) < ngrp
    row, col = _unpack(jnp.where(valid, ukey, 0), shape, order)
    row = jnp.where(valid, row, SENTINEL)
    col = jnp.where(valid, col, SENTINEL)
    vdims = vals.shape[1:]
    vm = valid.reshape((-1,) + (1,) * len(vdims))
    val = jnp.where(vm, out_vals, jnp.asarray(add.identity, out_vals.dtype))
    return COO(row, col, val, ngrp.astype(jnp.int32), shape, order)


@_obs.timed("merge.sort")
def sort_packed(c, order: str = "row"):
    """Packed-key argsort + one gather (COO.sort's engine implementation)."""
    from .coo import COO
    if c.order == order:
        return c
    keys = None if _FORCE_LEGACY else pack_keys(c.row, c.col, c.shape, order)
    if keys is None:
        return sort_two_key(c, order)
    perm = jnp.argsort(keys)                                 # stable
    return COO(c.row[perm], c.col[perm], c.val[perm], c.nnz, c.shape, order)


@_obs.timed("merge.dedup")
def dedup(c, add: Monoid, order: str = "row"):
    """Merge duplicate (row, col) entries (COO.dedup's engine implementation).

    Tagged inputs skip the argsort (``dedup_sorted``); untagged inputs pay
    one packed-key argsort + one value gather.
    """
    keys = None if _FORCE_LEGACY else pack_keys(c.row, c.col, c.shape, order)
    if keys is None:
        return dedup_legacy(c, add, order)
    if c.order == order:
        vals = c.val
    else:
        keys, vals = _sort_kv(keys, c.val)
    return _reduce_runs(keys, vals, c.nnz, c.shape, add, order)


def dedup_sorted(c, add: Monoid):
    """Sort-free dedup for tiles carrying an order tag (§4.3 invariant).

    Precondition: ``c.order`` in {'row','col'} and the device arrays honor
    it (canonical padding at the end). Pure O(n): boundary scan + segmented
    reduction, no sort of any kind.
    """
    assert c.order in ("row", "col"), \
        "dedup_sorted needs an order tag; use dedup() for untagged tiles"
    return dedup(c, add, c.order)


# --------------------------------------------------------------------------
# merge path (binary merge scheme, paper §5)
# --------------------------------------------------------------------------

def merge_sorted(a, b, add: Monoid, order: str = "row"):
    """C = A ⊕ B for two sorted tiles of the same shape — O(n), sort-free.

    Rank placement: output position of A[i] is ``i + |{kb < ka[i]}|`` and of
    B[j] is ``j + |{ka <= kb[j]}|`` (two searchsorteds). The two position
    sets are a bijection onto [0, capA+capB) with A's duplicates preceding
    B's, so two scatters materialize the merged sorted stream; equal keys
    then fuse in the same O(n) run reduction as ``dedup_sorted``.

    Inputs not carrying the order tag are packed-sorted first. Returns an
    exact-capacity (capA+capB) COO; callers clamp with ``with_cap`` after
    checking ``nnz`` against their budget.
    """
    assert a.shape == b.shape, (a.shape, b.shape)
    kd = key_dtype(a.shape)
    if kd is None:                        # unpackable tile: legacy concat
        from .coo import COO
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
        both = COO(jnp.concatenate([a.row, b.row]),
                   jnp.concatenate([a.col, b.col]),
                   jnp.concatenate([a.val.astype(out_dtype),
                                    b.val.astype(out_dtype)]),
                   a.nnz + b.nnz, a.shape, "none")
        return dedup_legacy(both, add, order)
    a = sort_packed(a, order)
    b = sort_packed(b, order)
    ka = pack_keys(a.row, a.col, a.shape, order)
    kb = pack_keys(b.row, b.col, b.shape, order)
    pos_a = jnp.arange(a.cap, dtype=jnp.int32) + \
        jnp.searchsorted(kb, ka, side="left").astype(jnp.int32)
    pos_b = jnp.arange(b.cap, dtype=jnp.int32) + \
        jnp.searchsorted(ka, kb, side="right").astype(jnp.int32)
    total = a.cap + b.cap
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    vdims = a.val.shape[1:]
    keys = jnp.zeros((total,), ka.dtype) \
        .at[pos_a].set(ka).at[pos_b].set(kb)
    vals = jnp.zeros((total,) + vdims, out_dtype) \
        .at[pos_a].set(a.val.astype(out_dtype)) \
        .at[pos_b].set(b.val.astype(out_dtype))
    return _reduce_runs(keys, vals, a.nnz + b.nnz, a.shape, add, order)


# --------------------------------------------------------------------------
# kv-level stage combining (the SpGEMM hot path)
#
# COO-level primitives rebuild (row, col, val, nnz) containers at every
# step. For SUMMA stage merging that is 3 gathers/scatters per array per
# level; the kv representation carries only (packed keys, values, count)
# through the whole pipeline and decodes rows/cols exactly once at the end.
# --------------------------------------------------------------------------

def _kv_dedup_window(keys, vals, nlive, add: Monoid, cap: int):
    """Sort + run-fuse one key/value window; slice to ``cap`` slots."""
    full_cap = keys.shape[0]
    kmax = jnp.asarray(jnp.iinfo(keys.dtype).max, keys.dtype)
    ks, vs = _sort_kv(keys, vals)
    gid, ngrp = _run_bounds(ks, nlive)
    out_v = segment_reduce(vs, gid, full_cap, add, sorted_ids=True)
    # group g's key via scatter-min (all keys in a group are equal)
    out_k = jnp.full((full_cap,), kmax, ks.dtype) \
        .at[gid].min(ks, mode="drop")
    ok = ngrp <= cap
    if cap < full_cap:
        out_k, out_v = out_k[:cap], out_v[:cap]
    return out_k, out_v, jnp.minimum(ngrp, cap).astype(jnp.int32), ok


def kv_from_products(rows, cols, vals, nprod, shape, add: Monoid,
                     cap: int, order: str = "row", mask=None):
    """One padded expansion buffer -> compacted sorted unique kv stream.

    The buffer is processed in windows of max(cap, full_cap/MAX_WINDOWS)
    slots. Expansion places live products CONTIGUOUSLY at the front, so
    windows past the live prefix are pure cap slack — a ``lax.cond`` skips
    their sort (and their merge) at runtime. The seed path sorted every
    slot of every stage buffer; here the work tracks the live product
    count, not the capacity guess (DESIGN.md §4.4 — the planner's ×safety
    slack costs ~nothing). Window distinct counts are bounded by the stage
    distinct count, so slicing every window stream to ``cap`` is lossless
    whenever the stage fits — the pre-slice ok checks catch when it
    doesn't. Returns (keys[cap], vals[cap], n, ok).

    ``mask`` (a ``mask.LocalMask``) is the mask-filter stage (§4.7): keys
    failing the sorted-membership probe become padding BEFORE any window
    sort, so non-mask products never enter the compaction or the merge tree
    — the pushdown that lets masked callers run with mask-sized ``cap``.
    ``nprod`` stays the pre-mask count: it only gates which windows can be
    skipped as all-slack, and the live prefix is unchanged by masking.
    """
    full_cap = rows.shape[0]
    keys = pack_keys(rows, cols, shape, order)
    assert keys is not None, "kv path requires a packable tile"
    if mask is not None:
        from .mask import mask_member            # lazy: mask.py imports us
        kmax = jnp.asarray(jnp.iinfo(keys.dtype).max, keys.dtype)
        # probe with keys packed in the MASK's order (may differ from the
        # pipeline's); the pipeline keys are only rewritten to padding
        probe = keys if mask.order == order \
            else pack_keys(rows, cols, shape, mask.order)
        keys = jnp.where(mask_member(probe, mask), keys, kmax)
    win = max(cap, full_cap // MAX_WINDOWS)
    if full_cap <= win or full_cap % win != 0:
        return _kv_dedup_window(keys, vals, nprod, add, cap)
    nwin = full_cap // win
    items = []
    ok = jnp.bool_(True)
    for t in range(nwin):
        sl = slice(t * win, (t + 1) * win)
        kw, vw = keys[sl], vals[sl]
        nw = jnp.clip(nprod - t * win, 0, win)
        # windows past the live prefix are all padding and already sorted
        # (the skip branch's static slice keeps the cap-sized stream shape)
        kt, vt, nt, okt = jax.lax.cond(
            nw > 0,
            lambda kw, vw, nw: _kv_dedup_window(kw, vw, nw, add, cap),
            lambda kw, vw, nw: (kw[:cap], vw[:cap],
                                jnp.zeros((), jnp.int32), jnp.bool_(True)),
            kw, vw, nw)
        ok = ok & okt
        items.append((kt, vt, nt))
    # fold the window streams pairwise; merges whose right side is empty
    # pass the left side through untouched (the slack never merges either)
    while len(items) > 1:
        nxt = []
        for i in range(0, len(items) - 1, 2):
            ka, va, na = items[i]
            kb, vb, nb = items[i + 1]
            km, vm, nm, okm = jax.lax.cond(
                nb > 0,
                lambda ka, va, na, kb, vb, nb: kv_merge2(
                    ka, va, na, kb, vb, nb, add, cap),
                lambda ka, va, na, kb, vb, nb: (ka, va, na, jnp.bool_(True)),
                ka, va, na, kb, vb, nb)
            ok = ok & okm
            nxt.append((km, vm, nm))
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    k, v, n = items[0]
    return k, v, n, ok


def kv_merge2(ka, va, na, kb, vb, nb, add: Monoid, cap: int):
    """Rank-placement merge of two UNIQUE-key sorted kv streams.

    Because each input is already deduplicated, a duplicate run in the
    interleaved stream has length exactly 2 (one entry from each side, A's
    placed first) — so duplicate fusion is one shifted compare + combine,
    no segmented reduction. Total: 2 searchsorteds + 4 scatters + a cumsum.

    Liveness is defined by the keys alone (dtype-max = padding, as
    pack_keys/kv_from_products produce); ``na``/``nb`` document the
    streams' counts for callers threading (k, v, n) triples but do not
    gate the merge — a stream with real keys past its count would merge
    them.
    """
    del na, nb
    ca, cb = ka.shape[0], kb.shape[0]
    kmax = jnp.asarray(jnp.iinfo(ka.dtype).max, ka.dtype)
    pos_a = jnp.arange(ca, dtype=jnp.int32) + \
        jnp.searchsorted(kb, ka, side="left").astype(jnp.int32)
    pos_b = jnp.arange(cb, dtype=jnp.int32) + \
        jnp.searchsorted(ka, kb, side="right").astype(jnp.int32)
    tot = ca + cb
    out_dtype = jnp.promote_types(va.dtype, vb.dtype)
    ident = jnp.asarray(add.identity, out_dtype)
    keys = jnp.full((tot,), kmax, ka.dtype) \
        .at[pos_a].set(ka).at[pos_b].set(kb)
    vals = jnp.full((tot,) + va.shape[1:], ident, out_dtype) \
        .at[pos_a].set(va.astype(out_dtype)) \
        .at[pos_b].set(vb.astype(out_dtype))
    live = keys != kmax
    nxt_k = jnp.concatenate([keys[1:], jnp.full((1,), kmax, keys.dtype)])
    nxt_v = jnp.concatenate(
        [vals[1:], jnp.full((1,) + vals.shape[1:], ident, out_dtype)])
    dup_next = (nxt_k == keys) & live
    if vals.ndim > 1:
        dup_next_v = dup_next.reshape((-1,) + (1,) * (vals.ndim - 1))
    else:
        dup_next_v = dup_next
    fused = jnp.where(dup_next_v, add.op(vals, nxt_v), vals)
    prev_k = jnp.concatenate([jnp.full((1,), -1, keys.dtype), keys[:-1]])
    dead = (keys == prev_k) & live          # the B copy of a fused pair
    alive = live & ~dead
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
    n_out = jnp.sum(alive).astype(jnp.int32)
    tgt = jnp.where(alive, pos, tot)
    out_k = jnp.full((tot,), kmax, keys.dtype).at[tgt].set(keys, mode="drop")
    out_v = jnp.full((tot,) + vals.shape[1:], ident, out_dtype) \
        .at[tgt].set(fused, mode="drop")
    cap = min(tot, cap)
    ok = n_out <= cap
    if cap < tot:
        out_k, out_v = out_k[:cap], out_v[:cap]
    return out_k, out_v, jnp.minimum(n_out, cap), ok


def kv_empty(shape, cap: int, val_dtype, add: Monoid, order: str = "row"):
    """Identity kv stream (the incremental-merge accumulator seed)."""
    kd = key_dtype(shape)
    assert kd is not None
    return (jnp.full((cap,), jnp.iinfo(kd).max, kd),
            jnp.full((cap,), add.identity, val_dtype),
            jnp.zeros((), jnp.int32))


def kv_to_coo(keys, vals, n, shape, add: Monoid, out_cap: int,
              order: str = "row"):
    """Decode a kv stream back to a canonical COO (the single decode)."""
    from .coo import COO
    cap = keys.shape[0]
    if cap < out_cap:
        kmax = jnp.asarray(jnp.iinfo(keys.dtype).max, keys.dtype)
        keys = jnp.concatenate(
            [keys, jnp.full((out_cap - cap,), kmax, keys.dtype)])
        vals = jnp.concatenate(
            [vals, jnp.full((out_cap - cap,) + vals.shape[1:],
                            add.identity, vals.dtype)])
    elif cap > out_cap:
        keys, vals = keys[:out_cap], vals[:out_cap]
    valid = jnp.arange(out_cap, dtype=jnp.int32) < n
    row, col = _unpack(jnp.where(valid, keys, 0), shape, order)
    row = jnp.where(valid, row, SENTINEL)
    col = jnp.where(valid, col, SENTINEL)
    vdims = vals.shape[1:]
    vm = valid.reshape((-1,) + (1,) * len(vdims))
    val = jnp.where(vm, vals, jnp.asarray(add.identity, vals.dtype))
    return COO(row, col, val, jnp.minimum(n, out_cap).astype(jnp.int32),
               shape, order)


def kv_tree(items, add: Monoid, out_cap: int):
    """Pairwise fold of unique-key kv streams. Returns (k, v, n, ok)."""
    assert len(items) >= 1
    items = list(items)
    ok = jnp.bool_(True)
    while len(items) > 1:
        nxt = []
        for i in range(0, len(items) - 1, 2):
            ka, va, na = items[i]
            kb, vb, nb = items[i + 1]
            k, v, n, o = kv_merge2(ka, va, na, kb, vb, nb, add, out_cap)
            ok = ok & o
            nxt.append((k, v, n))
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    k, v, n = items[0]
    ok = ok & (n <= out_cap)
    if _faults.trace_fault("merge.kv_ok") is not None:
        # trace-time fault: the kv engine's overflow flag lies (reads as
        # failed) on every call while armed — drives the planner into the
        # degradation ladder (the 'sort' merge path never enters kv_tree)
        ok = jnp.zeros_like(ok)
    return k, v, n, ok


def merge_stage_products(stages, shape, add: Monoid, stage_cap: int,
                         out_cap: int, order: str = "row", mask=None):
    """Deferred merge tree over raw expansion buffers (DESIGN.md §4.4).

    ``stages``: list of (rows, cols, vals, nprod) padded product buffers.
    Each stage is compacted (kv_from_products) to ``stage_cap`` slots, the
    compacted streams fold pairwise, and rows/cols decode once at the end.
    ``mask`` (a ``mask.LocalMask``) filters every stage's products before
    its compaction, so masked callers pass mask-sized caps (§4.7).
    Returns (COO, ok).
    """
    items = []
    ok = jnp.bool_(True)
    for (r, c, v, n) in stages:
        k, vv, ng, o = kv_from_products(r, c, v, n, shape, add, stage_cap,
                                        order, mask=mask)
        ok = ok & o
        items.append((k, vv, ng))
    k, v, n, o = kv_tree(items, add, out_cap)
    return kv_to_coo(k, v, n, shape, add, out_cap, order), ok & o


def merge_capped(a, b, add: Monoid, cap: int, order: str = "row"):
    """merge_sorted clamped to ``cap``; ok is the PRE-clamp overflow check."""
    m = merge_sorted(a, b, add, order)
    ok = m.nnz <= cap
    return m.with_cap(cap, add.identity), ok


@_obs.timed("merge.tree")
def merge_tree(tiles: Sequence, add: Monoid, out_cap: int,
               order: str = "row"):
    """Pairwise merge of q sorted stage buffers (the SUMMA multiway merge).

    Intermediate capacities grow as min(capL+capR, out_cap): a partial
    merge's distinct count is bounded by the final nnz(C), so clamping
    intermediates to ``out_cap`` is lossless whenever the final result fits
    — and the pre-clamp ``ok`` checks catch the case it doesn't (the
    planner's retry loop then grows the caps). Returns (COO, ok).
    """
    assert len(tiles) >= 1
    tiles = list(tiles)
    ok = jnp.bool_(True)
    while len(tiles) > 1:
        nxt = []
        for i in range(0, len(tiles) - 1, 2):
            m = merge_sorted(tiles[i], tiles[i + 1], add, order)
            tgt = min(m.cap, out_cap)
            ok = ok & (m.nnz <= tgt)
            nxt.append(m.with_cap(tgt, add.identity))
        if len(tiles) % 2:
            nxt.append(tiles[-1])
        tiles = nxt
    final = tiles[0]
    ok = ok & (final.nnz <= out_cap)
    return final.with_cap(out_cap, add.identity), ok
