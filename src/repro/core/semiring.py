"""Generalized semiring support (CombBLAS 2.0 §1 "User-Defined Operations").

A semiring here is ``(add-monoid, mul)`` where the add monoid carries its
identity (the sparse "zero": entries equal to it are *not stored*) and an
optional ``tag`` naming a hardware-fast reduction. CombBLAS 2.0's headline
generalization — heterogeneous algebras, where the two inputs and the output
come from *different* sets — is supported directly: ``mul`` may accept two
different dtypes (even vector-valued elements) and produce a third; the add
monoid only ever sees the output type.

Anything jit-traceable works as ``add``/``mul``; tagged monoids additionally
get XLA's native segment reductions and the MXU path in the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array

_FAST_TAGS = ("sum", "min", "max")


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Associative, commutative binary op with identity.

    ``tag`` ∈ {'sum','min','max',None}: names a reduction XLA implements
    natively (used by ``segment_reduce`` fast paths and by kernels). ``None``
    selects the generic sorted segmented-scan path, which accepts *any*
    jit-traceable associative op.
    """

    op: Callable[[Any, Any], Any]
    identity: Any
    tag: str | None = None
    name: str = "monoid"

    def identity_like(self, dtype, vdims: tuple[int, ...] = ()) -> Array:
        return jnp.full(vdims, self.identity, dtype=dtype) if vdims else jnp.asarray(
            self.identity, dtype=dtype
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Monoid({self.name}, tag={self.tag})"


@dataclasses.dataclass(frozen=True)
class Semiring:
    """``add`` is a Monoid over the output set; ``mul`` maps (a, b) -> c.

    ``add.identity`` must annihilate ``mul`` (mul(zero, x) == zero) for
    implicit sparse zeros to be correct — the classical GraphBLAS contract.
    """

    add: Monoid
    mul: Callable[[Any, Any], Any]
    name: str = "semiring"

    def out_dtype(self, a_dtype, b_dtype):
        """Result dtype of ``mul`` under JAX promotion (heterogeneous OK)."""
        a = jax.eval_shape(self.mul, jax.ShapeDtypeStruct((), a_dtype),
                           jax.ShapeDtypeStruct((), b_dtype))
        return a.dtype

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Semiring({self.name})"


# --------------------------------------------------------------------------
# Standard monoids / semirings
# --------------------------------------------------------------------------

PLUS = Monoid(jnp.add, 0, "sum", "plus")
MIN = Monoid(jnp.minimum, jnp.inf, "min", "min")
MAX = Monoid(jnp.maximum, -jnp.inf, "max", "max")
MIN_INT = Monoid(jnp.minimum, 2**31 - 1, "min", "min_int")
MAX_INT = Monoid(jnp.maximum, -(2**31) + 1, "max", "max_int")
LOR = Monoid(jnp.logical_or, False, "max", "lor")  # or == max over bool
LAND = Monoid(jnp.logical_and, True, "min", "land")
TIMES_MONOID = Monoid(jnp.multiply, 1, None, "times")


def _select2nd(a, b):
    del a
    return b


ARITHMETIC = Semiring(PLUS, jnp.multiply, "plus_times")
BOOLEAN = Semiring(LOR, jnp.logical_and, "lor_land")
MIN_PLUS = Semiring(MIN, jnp.add, "min_plus")          # tropical / shortest path
MAX_PLUS = Semiring(MAX, jnp.add, "max_plus")
MAX_MIN = Semiring(MAX, jnp.minimum, "max_min")        # bottleneck paths
MIN_MAX = Semiring(MIN, jnp.maximum, "min_max")
MIN_SELECT2ND = Semiring(MIN, _select2nd, "min_select2nd")      # BFS parents
MAX_SELECT2ND = Semiring(MAX, _select2nd, "max_select2nd")
MIN_INT_SELECT2ND = Semiring(MIN_INT, _select2nd, "min_int_select2nd")
PLUS_FIRST = Semiring(PLUS, lambda a, b: a, "plus_first")
PLUS_SECOND = Semiring(PLUS, _select2nd, "plus_second")
PLUS_PAIR = Semiring(PLUS, lambda a, b: jnp.ones((), a.dtype if hasattr(a, "dtype") else jnp.float32), "plus_pair")


def semiring(add_op, add_identity, mul_op, *, tag=None, name="user") -> Semiring:
    """Construct a user-defined semiring from plain callables."""
    return Semiring(Monoid(add_op, add_identity, tag, name + "_add"), mul_op, name)


# --------------------------------------------------------------------------
# Segment reduction under an arbitrary monoid
# --------------------------------------------------------------------------

# Optional accelerator backend (kernels/segreduce.py registers the Pallas
# segmented-reduce kernel here). The backend is called first for tagged
# monoids; returning None falls through to the pure-JAX paths below.
# Resolution is lazy: the first tagged segment_reduce asks the kernels
# layer to auto-register (TPU / REPRO_SEGREDUCE=pallas), so plain CPU runs
# never pay the pallas import and keep XLA's native segment ops.
_SEGREDUCE_BACKEND = None
_SEGREDUCE_RESOLVED = False


def register_segment_reduce_backend(fn) -> None:
    """Install ``fn(values, seg_ids, num_segments, tag, identity) ->
    Array | None`` as the tagged-monoid segment_reduce backend (None
    uninstalls; also pins resolution so lazy auto-register won't rerun)."""
    global _SEGREDUCE_BACKEND, _SEGREDUCE_RESOLVED
    _SEGREDUCE_BACKEND = fn
    _SEGREDUCE_RESOLVED = True


def _resolve_segreduce_backend() -> None:
    global _SEGREDUCE_RESOLVED
    if _SEGREDUCE_RESOLVED:
        return
    _SEGREDUCE_RESOLVED = True
    import os
    if os.environ.get("REPRO_SEGREDUCE", "").lower() not in ("1", "pallas") \
            and jax.default_backend() != "tpu":
        return                  # CPU/GPU: skip even the pallas import
    try:
        from ..kernels import segreduce
        segreduce.register()
    except ImportError:  # pragma: no cover - pallas unavailable
        pass


def _segmented_scan_reduce(values: Array, seg_ids: Array, num_segments: int,
                           monoid: Monoid) -> Array:
    """Generic path: values sorted by ``seg_ids``. O(n log n) associative scan.

    combine((k1,v1),(k2,v2)) = (k2, add(v1,v2) if k1==k2 else v2) is
    associative when the sequence is sorted by key; the running value at the
    last slot of each segment is the segment reduction.
    """

    def combine(l, r):
        lk, lv = l
        rk, rv = r
        same = (lk == rk)
        if values.ndim > 1:
            samev = same.reshape(same.shape + (1,) * (values.ndim - 1))
        else:
            samev = same
        return rk, jnp.where(samev, monoid.op(lv, rv), rv)

    _, scanned = jax.lax.associative_scan(combine, (seg_ids, values))
    n = seg_ids.shape[0]
    nxt = jnp.concatenate([seg_ids[1:], jnp.full((1,), -1, seg_ids.dtype)])
    is_last = seg_ids != nxt
    out = jnp.full((num_segments,) + values.shape[1:], monoid.identity,
                   dtype=values.dtype)
    # write each segment's last scanned value; out-of-range ids are dropped
    tgt = jnp.where(is_last, seg_ids, num_segments)
    out = out.at[tgt].set(scanned, mode="drop")
    return out


def segment_reduce(values: Array, seg_ids: Array, num_segments: int,
                   monoid: Monoid, *, sorted_ids: bool = False) -> Array:
    """Reduce ``values`` by ``seg_ids`` under ``monoid``.

    ids >= num_segments (padding) are dropped. A registered accelerator
    backend (the Pallas segreduce kernel) takes tagged scalar streams
    first; remaining fast paths use XLA's native segment ops; the generic
    path requires (and if needed performs) a sort.
    """
    if monoid.tag in _FAST_TAGS:
        if not _SEGREDUCE_RESOLVED:
            _resolve_segreduce_backend()
        if _SEGREDUCE_BACKEND is not None:
            out = _SEGREDUCE_BACKEND(values, seg_ids, num_segments,
                                     monoid.tag, monoid.identity)
            if out is not None:
                return out
    if monoid.tag == "sum":
        return jax.ops.segment_sum(values, seg_ids, num_segments,
                                   indices_are_sorted=sorted_ids)
    if monoid.tag == "min":
        out = jax.ops.segment_min(values, seg_ids, num_segments,
                                  indices_are_sorted=sorted_ids)
        return jnp.where(_touched(seg_ids, num_segments, values), out,
                         jnp.asarray(monoid.identity, values.dtype))
    if monoid.tag == "max":
        out = jax.ops.segment_max(values, seg_ids, num_segments,
                                  indices_are_sorted=sorted_ids)
        return jnp.where(_touched(seg_ids, num_segments, values), out,
                         jnp.asarray(monoid.identity, values.dtype))
    if not sorted_ids:
        order = jnp.argsort(seg_ids)
        seg_ids = seg_ids[order]
        values = values[order]
    return _segmented_scan_reduce(values, seg_ids, num_segments, monoid)


def _touched(seg_ids, num_segments, values):
    hit = jax.ops.segment_sum(jnp.ones_like(seg_ids), seg_ids, num_segments) > 0
    if values.ndim > 1:
        hit = hit.reshape(hit.shape + (1,) * (values.ndim - 1))
    return hit


# --------------------------------------------------------------------------
# Dense semiring contraction (reference + fallback for non-MXU semirings)
# --------------------------------------------------------------------------

def dense_semiring_matmul(a: Array, b: Array, sr: Semiring,
                          k_chunk: int = 512) -> Array:
    """C[i,j] = add_k mul(A[i,k], B[k,j]) for dense A (m,k), B (k,n).

    Fast path: arithmetic semiring -> jnp.dot (MXU). Otherwise a k-chunked
    broadcast-reduce that keeps peak memory at m*n*k_chunk.
    """
    if sr.add.tag == "sum" and sr.mul in (jnp.multiply,):
        return jnp.dot(a, b)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = sr.out_dtype(a.dtype, b.dtype)
    ident = jnp.asarray(sr.add.identity, out_dtype)
    nchunk = max(1, -(-k // k_chunk))
    kp = nchunk * k_chunk
    a_p = jnp.pad(a, ((0, 0), (0, kp - k)), constant_values=0)
    b_p = jnp.pad(b, ((0, kp - k), (0, 0)), constant_values=0)
    # padding contributes mul(0_a, 0_b); to keep identity semantics we mask it
    kc_pow2 = 1
    while kc_pow2 < k_chunk:
        kc_pow2 *= 2

    def body(carry, idx):
        a_c = jax.lax.dynamic_slice_in_dim(a_p, idx * k_chunk, k_chunk, 1)
        b_c = jax.lax.dynamic_slice_in_dim(b_p, idx * k_chunk, k_chunk, 0)
        prod = sr.mul(a_c[:, :, None], b_c[None, :, :])  # (m, kc, n)
        kk = idx * k_chunk + jnp.arange(k_chunk)
        prod = jnp.where((kk < k)[None, :, None], prod, ident)
        # log-depth pairwise tree over the chunk axis: emits O(log k_chunk)
        # ops instead of the k_chunk-long sequential chain (a 512-op
        # compile-time blowup for non-arithmetic semirings)
        red = prod
        if kc_pow2 != k_chunk:
            red = jnp.concatenate(
                [red, jnp.full((m, kc_pow2 - k_chunk, n), ident, out_dtype)],
                axis=1)
        while red.shape[1] > 1:
            half = red.shape[1] // 2
            red = sr.add.op(red[:, :half, :], red[:, half:, :])
        return sr.add.op(carry, red[:, 0, :]), None

    init = jnp.full((m, n), ident, out_dtype)
    out, _ = jax.lax.scan(body, init, jnp.arange(nchunk))
    return out


def dense_semiring_matvec(a: Array, x: Array, sr: Semiring) -> Array:
    """y[i] = add_k mul(A[i,k], x[k]) — dense reference for SpMV tests."""
    if sr.add.tag == "sum" and sr.mul in (jnp.multiply,):
        return a @ x
    prod = sr.mul(a, x[None, :])
    out_dtype = prod.dtype
    ident = jnp.asarray(sr.add.identity, out_dtype)
    red = jnp.full((a.shape[0],), ident, out_dtype)
    def body(i, red):
        return sr.add.op(red, prod[:, i])
    return jax.lax.fori_loop(0, a.shape[1], body, red)
