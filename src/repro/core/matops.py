"""Distributed matrix/vector helper operations used by the graph apps.

All are thin shard_map wrappers over the local COO ops; piece-aligned
operations (masking a sparse vector with a dense vector in the same layout,
elementwise tile ops between matrices on the same grid) need NO
communication — the payoff of CombBLAS's superimposed distributions.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map
from .coo import COO, SENTINEL
from .dist import DistSpMat, DistSpVec, DistVec, specs_of
from .semiring import Monoid, segment_reduce

Array = jax.Array


def mat_apply_local(a: DistSpMat, fn, *, mesh: Mesh) -> DistSpMat:
    """Apply ``fn: COO -> COO`` (same capacity) tile-wise.

    The result's order tag is whatever ``fn`` reports on the traced tile
    (COO.order is trace-static), so order-preserving fns keep the invariant.
    """
    out_order = []

    def body(at):
        t = fn(at.tile())
        out_order.append(t.order)
        return (t.row[None, None], t.col[None, None], t.val[None, None],
                t.nnz[None, None])

    row, col, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a),),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a)
    return DistSpMat(row, col, val, nnz, a.shape, a.grid,
                     order=out_order[0])


def mat_ewise_local(a: DistSpMat, b: DistSpMat, fn, *, mesh: Mesh) \
        -> DistSpMat:
    """fn: (COO, COO) -> COO on aligned tiles (same grid) — no comm."""
    assert a.grid == b.grid and a.shape == b.shape
    out_order = []

    def body(at, bt):
        t = fn(at.tile(), bt.tile())
        out_order.append(t.order)
        return (t.row[None, None], t.col[None, None], t.val[None, None],
                t.nnz[None, None])

    row, col, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a), specs_of(b)),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a, b)
    return DistSpMat(row, col, val, nnz, a.shape, a.grid,
                     order=out_order[0])


def mat_reduce(a: DistSpMat, axis: int, add: Monoid, *, mesh: Mesh) \
        -> DistVec:
    """Row (axis=1) or column (axis=0) reduction → DistVec.

    axis=1: result over rows, layout 'row' (psum along 'col', scattered).
    axis=0: result over cols, layout 'col' (psum along 'row', scattered).
    """

    def body(at):
        t = at.tile()
        local = t.reduce(axis, add)          # (mb,) or (nb,)
        red_axis = "col" if axis == 1 else "row"
        if add.tag == "sum":
            piece = jax.lax.psum_scatter(local, red_axis,
                                         scatter_dimension=0, tiled=True)
        else:
            q = a.grid[1] if axis == 1 else a.grid[0]
            parts = jax.lax.all_gather(local, red_axis)
            red = parts[0]
            for s in range(1, q):
                red = add.op(red, parts[s])
            k = jax.lax.axis_index(red_axis)
            piece = red.reshape(q, -1)[k]
        return piece[None, None]

    out = shard_map(body, mesh=mesh, in_specs=(specs_of(a),),
                        out_specs=P("row", "col", None))(a)
    n = a.shape[0] if axis == 1 else a.shape[1]
    return DistVec(out, n, a.grid, "row" if axis == 1 else "col")


def mat_scale_cols(a: DistSpMat, v: DistVec, mul=jnp.multiply, *,
                   mesh: Mesh) -> DistSpMat:
    """A[:, j] *= v[j]. v layout 'col' (gathered along 'row' like SpMV x)."""
    assert v.layout == "col"

    def body(at, xd):
        t = at.tile()
        xj = jax.lax.all_gather(xd.reshape(-1), "row", tiled=True)
        t2 = t.scale_cols(xj, mul)
        return (t2.row[None, None], t2.col[None, None], t2.val[None, None],
                t2.nnz[None, None])

    row, col, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a), P("row", "col", None)),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a, v.data)
    return DistSpMat(row, col, val, nnz, a.shape, a.grid, order=a.order)


def mat_scale_rows(a: DistSpMat, v: DistVec, mul=jnp.multiply, *,
                   mesh: Mesh) -> DistSpMat:
    """A[i, :] *= v[i]. v layout 'row' (gathered along 'col')."""
    assert v.layout == "row"

    def body(at, xd):
        t = at.tile()
        xi = jax.lax.all_gather(xd.reshape(-1), "col", tiled=True)
        t2 = t.scale_rows(xi, mul)
        return (t2.row[None, None], t2.col[None, None], t2.val[None, None],
                t2.nnz[None, None])

    row, col, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a), P("row", "col", None)),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a, v.data)
    return DistSpMat(row, col, val, nnz, a.shape, a.grid, order=a.order)


def mat_transpose(a: DistSpMat, *, mesh: Mesh) -> DistSpMat:
    """A^T on a square grid: swap tiles across the diagonal + local swap."""
    pr, pc = a.grid
    assert pr == pc
    q = pr
    perm = [(i * q + j, j * q + i) for i in range(q) for j in range(q)]

    def body(at):
        f = lambda t: jax.lax.ppermute(t, ("row", "col"), perm)
        return (f(at.col), f(at.row), f(at.val), f(at.nnz))

    col, row, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a),),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a)
    # note the (col, row) swap above: returned fields are already transposed;
    # (row, col)-sorted tiles become (col, row)-sorted in the new coordinates
    t_order = {"row": "col", "col": "row"}.get(a.order, "none")
    return DistSpMat(col, row, val, nnz, (a.shape[1], a.shape[0]), a.grid,
                     order=t_order)


def mat_select_lower(a: DistSpMat, *, mesh: Mesh, strict=True) -> DistSpMat:
    """Keep entries with global row > col (strict lower triangle)."""
    mb, nb = a.mb, a.nb

    def body(at):
        t = at.tile()
        i = jax.lax.axis_index("row")
        j = jax.lax.axis_index("col")
        grow = t.row.astype(jnp.int64) + i.astype(jnp.int64) * mb
        gcol = t.col.astype(jnp.int64) + j.astype(jnp.int64) * nb
        keep = (grow > gcol) if strict else (grow >= gcol)
        t2 = _prune_mask(t, keep)
        return (t2.row[None, None], t2.col[None, None], t2.val[None, None],
                t2.nnz[None, None])

    row, col, val, nnz = shard_map(
        body, mesh=mesh, in_specs=(specs_of(a),),
        out_specs=(P("row", "col", None), P("row", "col", None),
                   P("row", "col", None), P("row", "col")))(a)
    return DistSpMat(row, col, val, nnz, a.shape, a.grid, order=a.order)


def _prune_mask(t: COO, keep: Array) -> COO:
    keep = keep & t.mask()
    order = jnp.argsort(~keep, stable=True)
    row = jnp.where(keep[order], t.row[order], SENTINEL)
    col = jnp.where(keep[order], t.col[order], SENTINEL)
    val = jnp.where(keep[order], t.val[order], 0)
    # stable compaction: surviving entries keep their relative order
    return COO(row, col, val, jnp.sum(keep).astype(jnp.int32), t.shape,
               t.order)


def mat_sum(a: DistSpMat) -> Array:
    """Σ stored values (arithmetic). Works on the sharded arrays directly."""
    return jnp.sum(jnp.where(a.row != SENTINEL, a.val, 0))


def mat_nnz(a: DistSpMat) -> Array:
    return jnp.sum(a.nnz)


# ---------------- piece-aligned vector ops (no communication) -------------

def vec_ewise(u: DistVec, v: DistVec, fn) -> DistVec:
    assert u.layout == v.layout and u.grid == v.grid
    return DistVec(fn(u.data, v.data), u.n, u.grid, u.layout)


def vec_apply(u: DistVec, fn) -> DistVec:
    return DistVec(fn(u.data), u.n, u.grid, u.layout)


def vec_sum(u: DistVec) -> Array:
    # padding beyond n is zero by construction in from_global; keep it so
    return jnp.sum(u.data)


def spvec_mask(x: DistSpVec, v: DistVec, keep_fn) -> DistSpVec:
    """Filter sparse entries by keep_fn(x_val, v_val_at_idx) — layouts must
    match so lookup is piece-local (no comm)."""
    assert x.layout == v.layout and x.grid == v.grid
    vb = v.data.shape[2]

    def per_piece(xi, xv, xn, vd):
        ok = (xi != SENTINEL)
        vals_at = vd[jnp.clip(xi, 0, vb - 1)]
        keep = ok & keep_fn(xv, vals_at)
        order = jnp.argsort(~keep, stable=True)
        ni = jnp.where(keep[order], xi[order], SENTINEL)
        nv = jnp.where(keep[order], xv[order], 0)
        return ni, nv, jnp.sum(keep).astype(jnp.int32)

    f = jax.vmap(jax.vmap(per_piece))
    ni, nv, nn = f(x.idx, x.val, x.nnz, v.data)
    return DistSpVec(ni, nv, nn, x.n, x.grid, x.layout)


def vec_scatter_spvec(v: DistVec, x: DistSpVec, fn) -> DistVec:
    """v[i] = fn(v[i], x[i]) for stored x entries — piece-aligned scatter."""
    assert x.layout == v.layout and x.grid == v.grid

    def per_piece(vd, xi, xv):
        cur = vd[jnp.clip(xi, 0, vd.shape[0] - 1)]
        new = fn(cur, xv)
        return vd.at[xi].set(new, mode="drop")

    return DistVec(jax.vmap(jax.vmap(per_piece))(v.data, x.idx, x.val),
                   v.n, v.grid, v.layout)


def spvec_nnz(x: DistSpVec) -> Array:
    return jnp.sum(x.nnz)
