"""Capacity planner — the paper's "rules of thumb", made executable.

CombBLAS 2.0 sizes SpGEMM outputs with a symbolic phase before the numeric
phase (§4.1) and gives scenario rules for picking data structures and
algorithm variants (§5, §7). JAX/XLA adds a twist: every buffer is a static
*capacity*, so a wrong guess either truncates (too small) or wastes memory
and compile cache (too large). This module centralizes the guessing:

  1. **Estimate** flops and nnz(C) from tile nnz statistics — a cheap
     symbolic pass over the host-resident ``DistSpMat.nnz`` array (p numbers
     per operand, no device work), or the exact ``spgemm_flops`` count for
     single tiles.
  2. **Derive** ``prod_cap`` / ``out_cap`` with a safety factor, quantized
     to powers of two so repeated planning reuses compiled executables.
  3. **Bound** every cap by a true worst case (products can never exceed
     nnz(A-tile)·nnz(B-tile) per stage; outputs never exceed the dense
     tile), so overflow-retry terminates.
  4. **Retry on overflow**: the kernels' ``ok`` flags are checked on the
     host; a failed attempt re-runs with grown caps instead of returning
     truncated results.
  5. **Pick variants** by the paper's rules of thumb (DESIGN.md §4.6):
     deferred vs incremental merge by product-buffer memory, rotation vs
     allgather by gathered-operand memory, SpMV vs SpMSpV (and the local
     SpMSpV data structure) by frontier density (§4.5, Fig 3).

Apps call ``spgemm`` / ``spmspv`` below with NO capacity arguments; explicit
caps remain available as overrides and short-circuit the estimator.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import recorder as _obs
from ..robust import (audit as _audit, deadline as _deadline,
                      faults as _faults, recover as _recover)
from .coo import COO
from .dist import DistSpMat, DistSpVec
from .local_spgemm import compression_ratio, spgemm_flops
from .semiring import ARITHMETIC, Semiring
from .spgemm import spgemm_2d as _spgemm_2d
from .spmv import spmspv as _spmspv_2d

# Per-device scratch budget for planner decisions, in *entries* (a COO entry
# is ~16 bytes with indices): ~64 MB. Crossing it flips the memory-saving
# variant choices; it never bounds correctness (caps still grow on retry).
MEM_BUDGET_ENTRIES = 1 << 22

# Below this many total product slots (q·prod_cap) the legacy single
# concat-and-sort merge beats the merge tree: per-stage compaction and the
# pairwise rank-placement merges carry fixed overheads that a few thousand
# entries never amortize (DESIGN.md §4.4/§4.6).
SORT_MERGE_ENTRIES = 1 << 13

# Hybrid-schedule rule of thumb (DESIGN.md §4.6/§4.8, after McFarland et
# al. arXiv 2504.06408): when the per-stage wire volumes are skewed
# (coefficient of variation of the stage operand sizes above this), batch
# the sparsest stages into one fused eager exchange (the all-to-all leg)
# and stream the dense stages as per-stage broadcasts. Uniform stages gain
# nothing from splitting the sweep, so they keep the rotate schedule.
HYBRID_STAGE_SKEW = 0.5

# Mask pushdown rule of thumb (DESIGN.md §4.6/§4.7): fused masking beats
# unmasked-then-filter when the mask admits at most this fraction of the
# unmasked output estimate — below it the mask-sized out/stage caps drop a
# pow2 tier and every merge stage shrinks; above it only the membership
# probe (O(log nnz(M)) per product) and the skipped post-filter pass remain,
# which is ~parity. Capacity shrinking applies the bound unconditionally
# (it is exact, not a heuristic); this constant documents where the *win*
# starts (the BENCH_spgemm.json masked rows track it).
MASK_PUSHDOWN_RATIO = 0.5


def _pow2(x: float, lo: int = 64) -> int:
    """Round up to a power of two (compile-cache-friendly cap quantization)."""
    return max(lo, 1 << math.ceil(math.log2(max(float(x), 1.0))))


def _host_nnz(a) -> np.ndarray:
    return np.asarray(jax.device_get(a.nnz), np.float64)


# --------------------------------------------------------------------------
# distributed SpGEMM
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpGEMMPlan:
    prod_cap: int          # per-stage expansion slots per device
    out_cap: int           # merged output entries per device
    variant: str           # 'rotation' | 'allgather'
    merge: str             # 'sort' | 'deferred' | 'incremental' (§4.4)
    prod_ceiling: int      # worst-case bound — retry growth stops here
    out_ceiling: int
    est_flops: float       # estimated peak per-device per-stage products
    est_out: float         # estimated peak per-device nnz(C)
    attempts: int = 1      # how many numeric attempts the retry loop used
    degraded: tuple = ()   # ladder rungs taken (robust/recover.py), in order
    # exchange schedule (§4.8): None derives from the variant; 'rotate' |
    # 'alltoall' | 'bcast' | a length-q tuple of 'bcast'|'gather' entries
    schedule: object = None
    overlap: bool = True   # double-buffered stage loops (False = bulk-sync)
    compress: Optional[str] = None   # 'int8' wire compression of values

    def at_ceiling(self) -> bool:
        return (self.prod_cap >= self.prod_ceiling
                and self.out_cap >= self.out_ceiling)

    def grown(self, factor: int = 4) -> "SpGEMMPlan":
        if (self.prod_cap >= self.prod_ceiling
                and self.out_cap >= self.out_ceiling):
            raise RuntimeError(
                "SpGEMM overflow at worst-case capacities "
                f"(prod_cap={self.prod_cap}, out_cap={self.out_cap}) — "
                "the ok flags disagree with the symbolic bound")
        return dataclasses.replace(
            self,
            prod_cap=min(self.prod_cap * factor, self.prod_ceiling),
            out_cap=min(self.out_cap * factor, self.out_ceiling),
            attempts=self.attempts + 1)


def plan_spgemm(a: DistSpMat, b: DistSpMat | None = None, *,
                safety: float = 4.0,
                prod_cap: int | None = None, out_cap: int | None = None,
                variant: str | None = None, merge: str | None = None,
                mask=None, schedule=None, overlap: bool = True,
                compress: str | None = None,
                mem_budget: int = MEM_BUDGET_ENTRIES) -> SpGEMMPlan:
    """Size and configure a 2D SpGEMM from tile nnz statistics.

    The estimate assumes entries spread uniformly over tile columns (the
    random-permutation load-balance story of §2.3); skewed inputs are caught
    by the overflow flags and absorbed by the safety factor + retry growth.

    ``schedule`` (§4.8): when neither variant nor schedule is forced, the
    planner inspects the per-stage wire volumes (stage k moves A(·,k) and
    B(k,·)); skewed stages (cv > ``HYBRID_STAGE_SKEW``) pick a hybrid
    per-stage tuple — the sparsest stages batched into one fused eager
    exchange ('gather'), the rest per-stage broadcasts ('bcast') — while
    uniform stages keep the variant-derived whole-sweep schedule.
    ``overlap`` and ``compress`` ride on the plan so the retry loop and the
    degradation ladder ('serial-schedule' rung) can flip them.

    ``mask`` (a ``mask.MaskSpec``): a pattern mask bounds the per-tile
    output EXACTLY — a structural mask's C tile holds at most its mask
    tile's nnz, a complement mask's at most dense-tile − nnz — so both the
    out estimate and the retry ceiling intersect with the mask stats and
    every mask-sized sort/merge stage shrinks with them (§4.7). Value-only
    masks have unknown selectivity and change nothing here.
    """
    b = a if b is None else b
    q = a.pr
    na = _host_nnz(a).reshape(q, q)
    nb_ = _host_nnz(b).reshape(q, q)
    inner = float(max(a.nb, 1))            # contraction dim of one tile pair

    # stage (i, j, k) multiplies A(i,k) · B(k,j): expected products under
    # uniform column occupancy, exact upper bound nnz_a * nnz_b
    pair = na[:, :, None] * nb_[None, :, :]          # [i, k, j] -> products
    stage_est = float(pair.max()) / inner
    stage_bound = float(pair.max())
    # per-device output: flops estimate summed over stages, capped by the
    # dense C tile (distinct (row, col) pairs cannot exceed it)
    flops_dev = np.einsum("ik,kj->ij", na, nb_) / inner
    dense_tile = float(a.mb) * float(b.nb)
    out_bound = min(stage_bound * q, dense_tile)
    if mask is not None and mask.mat is not None:
        mn = _host_nnz(mask.mat).reshape(q, q)
        if not mask.complement:
            # structural (pred or not): members ⊆ stored entries
            mask_bound = float(mn.max())
        elif mask.pred is None:
            # complement: admissible slots = dense tile − stored entries
            mask_bound = float(dense_tile - mn.min())
        else:
            # complement of a pred-subselected mask admits UP TO the dense
            # tile (pred may reject every stored entry) — no valid shrink
            mask_bound = dense_tile
        out_bound = min(out_bound, max(mask_bound, 1.0))
    out_est = float(min(flops_dev.max(), out_bound))

    p_ceil = _pow2(stage_bound)
    o_ceil = _pow2(out_bound)
    p_cap = min(_pow2(prod_cap or safety * stage_est), p_ceil)
    o_cap = min(_pow2(out_cap or safety * out_est), o_ceil)
    if prod_cap:
        p_cap = max(p_cap, _pow2(prod_cap))   # explicit override wins
        p_ceil = max(p_ceil, p_cap)
    if out_cap:
        o_cap = max(o_cap, _pow2(out_cap))
        o_ceil = max(o_ceil, o_cap)

    # rules of thumb (DESIGN.md §4.6): allgather materializes q stage
    # operands at once — fine on small grids, memory-hostile at scale.
    # Merge strategy (§4.4), from stage count and nnz stats:
    #   - tiny total product volume: the legacy single concat-and-sort has
    #     no per-stage fixed costs to amortize -> 'sort';
    #   - q·prod_cap beyond the scratch budget: 'incremental' (O(out_cap)
    #     accumulator, one stage buffer live at a time);
    #   - otherwise 'deferred' (per-stage compaction + merge tree) — but
    #     only where it wins: the engine's sorts track live products, so it
    #     needs real cap slack to skip (prod_cap ≥ 4·expected products) and
    #     its tree work (≈ out_cap·log2 q rank-placement slots) must stay
    #     well under the q·prod_cap sort volume it avoids.
    if variant is None and schedule is not None:
        # explicit schedule, free variant: keep the pair consistent
        variant = ("rotation" if schedule == "rotate" else
                   "allgather" if schedule == "alltoall" else "hybrid")
    auto_sched = variant is None and schedule is None
    if variant is None:
        variant = "allgather" if q * (a.cap + b.cap) <= mem_budget \
            else "rotation"
    if merge is None:
        tree_slots = o_cap * max(math.log2(max(q, 2)), 1.0)
        if q * p_cap <= SORT_MERGE_ENTRIES:
            merge = "sort"
        elif q * p_cap > mem_budget:
            merge = "incremental"
        elif p_cap >= 4 * stage_est and tree_slots <= q * p_cap / 4:
            merge = "deferred"
        else:
            merge = "sort"
    if schedule is None and auto_sched and variant == "rotation" and q >= 2:
        # per-stage schedule pick (§4.8): stage k moves A(·,k)/B(k,·); when
        # the stage volumes are skewed, eagerly batch the sparsest stages
        # (one fused exchange — the all-to-all leg) and broadcast the rest
        # per stage. The gather count is memory-bounded: each batched stage
        # keeps one extra operand pair live.
        sk = na.max(axis=0) + nb_.max(axis=1)
        cv = float(sk.std() / max(sk.mean(), 1.0))
        g = int(min(q - 1, mem_budget // max(a.cap + b.cap, 1)))
        if cv > HYBRID_STAGE_SKEW and g >= 1:
            sparsest = set(int(k) for k in np.argsort(sk)[:g])
            schedule = tuple("gather" if k in sparsest else "bcast"
                             for k in range(q))
            variant = "hybrid"
    return SpGEMMPlan(p_cap, o_cap, variant, merge, p_ceil, o_ceil,
                      stage_est, out_est, schedule=schedule, overlap=overlap,
                      compress=compress)


def spgemm(a: DistSpMat, b: DistSpMat | None = None,
           sr: Semiring = ARITHMETIC, *, mesh,
           plan: SpGEMMPlan | None = None,
           prod_cap: int | None = None, out_cap: int | None = None,
           variant: str | None = None, merge: str | None = None,
           mask=None, schedule=None, overlap: bool = True,
           compress: str | None = None,
           safety: float = 4.0, max_attempts: int = 6, growth: int = 4):
    """Planned C = A ⊕.⊗ B (optionally C⟨M⟩ via ``mask``). Returns
    (C, plan-with-attempt-count).

    An overflowing attempt (any device's ``ok`` flag false) is retried with
    caps grown ×``growth`` toward the worst-case ceiling — never a silently
    truncated result. Caps quantize to powers of two, so steady-state
    iterative callers (HipMCL) reuse the compiled executable. Pattern masks
    shrink the planned out/stage capacities to the mask-intersected
    estimate (§4.7), with the same retry loop as the safety net.

    Robustness (robust/): a failed audit (checksum mismatch across a comm
    boundary, invariant violation) counts as a failed attempt and re-runs
    from the pristine host-side inputs; when plain retries keep failing —
    caps at the worst-case ceiling with ok still false, attempts exhausted,
    or persistent audit failures — the degradation ladder
    (``recover.next_rung``) swaps in progressively more conservative
    pipeline pieces, one loud warning each, recorded in ``plan.degraded``.
    Only when the ladder is exhausted does this raise.
    """
    b = a if b is None else b
    p = plan if plan is not None else plan_spgemm(
        a, b, safety=safety, prod_cap=prod_cap, out_cap=out_cap,
        variant=variant, merge=merge, mask=mask, schedule=schedule,
        overlap=overlap, compress=compress)
    _plan_event("plan.spgemm", p)
    cur_mask = mask
    post_mask = None       # set when the 'postfilter' rung strips the mask
    audit_fails = 0
    while True:
        try:
            c, ok = _spgemm_2d(a, b, sr, mesh=mesh, prod_cap=p.prod_cap,
                               out_cap=p.out_cap, variant=p.variant,
                               merge=p.merge, mask=cur_mask,
                               schedule=p.schedule, overlap=p.overlap,
                               compress=p.compress)
        except _audit.AuditError as err:
            audit_fails += 1
            timeout = isinstance(err, _deadline.ExchangeTimeout)
            _obs.event("plan.audit_retry", op="spgemm", site=err.site,
                       attempt=p.attempts, fails=audit_fails,
                       timeout=timeout)
            _obs.counter_add("plan.audit_retries")
            if audit_fails <= MAX_AUDIT_RETRIES:
                warnings.warn(
                    f"SpGEMM attempt {p.attempts} failed audit at "
                    f"{err.site}: {err} — retrying from pristine inputs "
                    f"({audit_fails}/{MAX_AUDIT_RETRIES})",
                    RuntimeWarning, stacklevel=2)
                if timeout:
                    # a deadline trip means a straggling peer, not a flipped
                    # bit — give the topology time to heal before hammering
                    # the same exchange (deterministic seeded backoff)
                    _deadline.backoff_sleep(err.site, audit_fails)
                p = dataclasses.replace(p, attempts=p.attempts + 1)
                continue
            rung = _recover.next_rung(p, cur_mask, kind="spgemm")
            if rung is None:
                if timeout:
                    raise _deadline.TopologyError(
                        f"SpGEMM exchange at {err.site} still over deadline "
                        f"after {p.attempts} attempts with the degradation "
                        f"ladder exhausted (degraded={p.degraded}) — the "
                        "topology, not the data, is at fault", err.site) \
                        from err
                raise
            p = _recover.apply_rung(rung, p)
            if timeout:
                # the shed schedule's exchanges have different timing: the
                # old trailing-median budget would trip spuriously
                _deadline.reset(err.site)
            p, cur_mask, post_mask = _spgemm_take_rung(
                rung, p, a, b, safety, cur_mask, post_mask)
            continue
        ok = _faults.flip_ok("plan.spgemm.ok", ok)
        if bool(jnp.all(ok)):
            if post_mask is not None:
                c = _recover.postfilter_2d(c, post_mask, sr, mesh=mesh)
            if p.attempts > 1 or p.degraded:
                _plan_event("plan.spgemm.done", p)
            return c, p
        if p.attempts < max_attempts and not p.at_ceiling():
            _obs.event("plan.overflow_retry", op="spgemm",
                       attempt=p.attempts, prod_cap=p.prod_cap,
                       out_cap=p.out_cap)
            _obs.counter_add("plan.overflow_retries")
            p = p.grown(growth)
            continue
        rung = _recover.next_rung(p, cur_mask, kind="spgemm")
        if rung is None:
            raise RuntimeError(
                f"SpGEMM still overflowing after {p.attempts} attempts "
                f"(prod_cap={p.prod_cap}, out_cap={p.out_cap}) — "
                f"degradation ladder exhausted (degraded={p.degraded})")
        p = _recover.apply_rung(rung, p)
        p, cur_mask, post_mask = _spgemm_take_rung(
            rung, p, a, b, safety, cur_mask, post_mask)


# Audit failures are retried from pristine inputs this many times before
# the retry loop escalates to the degradation ladder (transient wire faults
# vs. a persistently-implicated pipeline stage).
MAX_AUDIT_RETRIES = 3


def _plan_event(kind: str, p):
    """One structured obs event carrying a plan's full decision record.

    Emitted when a plan is adopted (``plan.spgemm`` / ``plan.spmspv``) and
    again at return when the retry loop changed it (``*.done``) — the
    flight-recorder view of the paper's rules of thumb in action. Free
    when obs is disabled (event() is one boolean read).
    """
    if not _obs.enabled():
        return
    s = getattr(p, "schedule", None)
    _obs.event(kind,
               variant=getattr(p, "variant", None),
               merge=getattr(p, "merge", None),
               schedule=s if (s is None or isinstance(s, str)) else "hybrid",
               overlap=getattr(p, "overlap", None),
               compress=getattr(p, "compress", None),
               prod_cap=p.prod_cap, out_cap=p.out_cap,
               attempts=p.attempts, degraded=",".join(p.degraded))


def _spgemm_take_rung(rung, p, a, b, safety, cur_mask, post_mask):
    """Post-``apply_rung`` bookkeeping the planner owns: the 'postfilter'
    rung strips the mask from the multiply (applied post-hoc on success),
    which invalidates the mask-shrunk capacities — re-plan for the unmasked
    output, keeping the grown caps as floors."""
    p = dataclasses.replace(p, attempts=p.attempts + 1)
    if rung != "postfilter":
        return p, cur_mask, post_mask
    fresh = plan_spgemm(a, b, safety=safety, variant=p.variant, merge=p.merge)
    p = dataclasses.replace(
        p,
        prod_cap=max(p.prod_cap, fresh.prod_cap),
        out_cap=max(p.out_cap, fresh.out_cap),
        prod_ceiling=max(p.prod_ceiling, fresh.prod_ceiling),
        out_ceiling=max(p.out_ceiling, fresh.out_ceiling))
    return p, None, cur_mask


def demote_stage(plan: SpGEMMPlan, stage: int, q: int) -> SpGEMMPlan:
    """Re-plan the hybrid schedule away from a persistently slow stage.

    The watchdog's straggler signal names an iteration, and the caller maps
    it to the exchange stage whose peer keeps lagging; demoting that stage
    from the per-stage broadcast to the batched ``'gather'`` leg takes its
    broadcast off the critical path (the gather stages exchange eagerly in
    one fused all-to-all up front — §4.8 hybrid schedule). The elastic
    ``CheckpointedLoop``'s ``on_straggler`` hook is the intended caller.

    Whole-sweep schedules (``rotate``/``alltoall``/None) first expand to the
    per-stage ``('bcast',) * q`` form; the result is always a length-``q``
    tuple schedule with ``variant='hybrid'``, recorded in ``plan.degraded``
    as ``demote-stage:<k>`` so degraded runs stay diagnosable.
    """
    if not 0 <= stage < q:
        raise ValueError(f"stage {stage} outside [0, q={q})")
    s = plan.schedule
    base = tuple(s) if isinstance(s, (tuple, list)) else ("bcast",) * q
    if len(base) != q:
        raise ValueError(
            f"plan schedule has {len(base)} stages, expected q={q}")
    if base[stage] == "gather":
        return plan                       # already off the broadcast path
    warnings.warn(
        f"robust: demoting exchange stage {stage} to the batched 'gather' "
        f"leg (persistent straggler; schedule was {s!r})",
        RuntimeWarning, stacklevel=2)
    _obs.event("ladder.demote_stage", stage=stage,
               schedule=s if (s is None or isinstance(s, str)) else "hybrid")
    _obs.counter_add("ladder.demotions")
    sched = base[:stage] + ("gather",) + base[stage + 1:]
    return dataclasses.replace(
        plan, schedule=sched, variant="hybrid",
        degraded=tuple(plan.degraded) + (f"demote-stage:{stage}",))


# --------------------------------------------------------------------------
# distributed SpMSpV / SpMV
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpMSpVPlan:
    prod_cap: int
    out_cap: int
    variant: str           # local kernel: 'sort' | 'bucket' | 'spa'
    merge: str             # 'sparse' | 'dense'
    use_spmv: bool         # rule of thumb: dense SpMV beats SpMSpV here
    prod_ceiling: int
    out_ceiling: int
    density: float
    attempts: int = 1
    degraded: tuple = ()   # ladder rungs taken (robust/recover.py), in order

    def at_ceiling(self) -> bool:
        return (self.prod_cap >= self.prod_ceiling
                and self.out_cap >= self.out_ceiling)

    def grown(self, factor: int = 4) -> "SpMSpVPlan":
        if (self.prod_cap >= self.prod_ceiling
                and self.out_cap >= self.out_ceiling):
            raise RuntimeError(
                "SpMSpV overflow at worst-case capacities "
                f"(prod_cap={self.prod_cap}, out_cap={self.out_cap})")
        return dataclasses.replace(
            self,
            prod_cap=min(self.prod_cap * factor, self.prod_ceiling),
            out_cap=min(self.out_cap * factor, self.out_ceiling),
            attempts=self.attempts + 1)


def spmspv_variant_for_density(density: float) -> str:
    """Fig-3 rule of thumb (§4.5): sort ≲0.5%, bucket ≲10%, SPA above."""
    if density < 0.005:
        return "sort"
    if density < 0.10:
        return "bucket"
    return "spa"


def plan_spmspv(a: DistSpMat, frontier_nnz: int, *, safety: float = 4.0,
                prod_cap: int | None = None, out_cap: int | None = None,
                variant: str | None = None, merge: str | None = None,
                add_tag: str | None = None,
                mask_allowed: int | None = None) -> SpMSpVPlan:
    """Size y = A·x for a sparse x with ``frontier_nnz`` stored entries.

    Expected per-device products = nnz(A_tile) · frontier density (each
    frontier column activates its share of tile entries); the exact worst
    case is the full tile, which bounds retry growth. ``add_tag`` (the
    semiring's add-monoid tag, if the caller knows it) lets the dense-merge
    rule of thumb apply — psum_scatter merging needs a 'sum' monoid.

    ``mask_allowed`` (mask-admissible output rows, ``mask_allowed_count``)
    bounds y's stored entries exactly — masked products are dropped inside
    the expansion (§4.7), so out caps (NOT prod caps: expansion still
    enumerates every flop) intersect with it. BFS's complement mask makes
    this shrink as the search saturates.
    """
    nt = _host_nnz(a)
    max_tile = float(nt.max()) if nt.size else 1.0
    pc = a.grid[1]
    n = max(a.shape[1], 1)
    f = max(int(frontier_nnz), 1)
    density = f / n
    est = max(max_tile * density, 1.0)
    p_ceil = _pow2(max_tile)
    # worst case for out_cap: the sparse merge buckets entries by
    # destination piece with out_cap // pc slots each, and ALL of a
    # partial's entries (≤ min(max_tile, mb)) may target one piece — the
    # ceiling therefore carries a ×pc factor, or skewed outputs would hit
    # the ceiling with ok still false and the retry loop would give up
    out_bound = min(max_tile, float(a.mb))
    out_est = est
    if mask_allowed is not None:
        allowed = float(max(int(mask_allowed), 1))
        out_bound = min(out_bound, allowed)
        out_est = min(out_est, allowed)
    o_ceil = _pow2(out_bound * pc)
    p_cap = min(_pow2(prod_cap or safety * est), p_ceil)
    o_cap = min(_pow2(out_cap or safety * out_est * pc), o_ceil)
    if prod_cap:
        p_cap = max(p_cap, _pow2(prod_cap))
        p_ceil = max(p_ceil, p_cap)
    if out_cap:
        o_cap = max(o_cap, _pow2(out_cap))
        o_ceil = max(o_ceil, o_cap)
    use_spmv = density > 0.30    # §4.5: SpMSpV stays competitive far past
    #                              where intuition says to switch
    if merge is None:
        # the SpMV rule of thumb made executable: for dense-ish frontiers
        # the dense-accumulator local kernel + psum_scatter merge IS the
        # classic SpMV pipeline (requires a natively-reducible monoid)
        merge = "dense" if use_spmv and add_tag == "sum" else "sparse"
    return SpMSpVPlan(
        prod_cap=p_cap, out_cap=o_cap,
        variant=variant or spmspv_variant_for_density(density),
        merge=merge,
        use_spmv=use_spmv,
        prod_ceiling=p_ceil,
        out_ceiling=o_ceil,
        density=density)


def spmspv(a: DistSpMat, x: DistSpVec, sr: Semiring, *, mesh,
           plan: SpMSpVPlan | None = None,
           prod_cap: int | None = None, out_cap: int | None = None,
           variant: str | None = None, merge: str | None = None,
           mask=None,
           safety: float = 4.0, max_attempts: int = 6, growth: int = 4):
    """Planned y = A·x (sparse x, optionally masked). Returns (DistSpVec,
    plan).

    Plans from the *current* frontier size (one host scalar), so iterative
    callers (BFS) get caps that track the frontier; power-of-two
    quantization keeps the number of distinct compilations logarithmic.
    A vector mask additionally caps the output at the admissible-row count.
    """
    if plan is None:
        allowed = None
        if mask is not None:
            from .mask import mask_allowed_count
            allowed = mask_allowed_count(mask)
        plan = plan_spmspv(
            a, int(jax.device_get(jnp.sum(x.nnz))), safety=safety,
            prod_cap=prod_cap, out_cap=out_cap, variant=variant, merge=merge,
            add_tag=sr.add.tag, mask_allowed=allowed)
    p = plan
    _plan_event("plan.spmspv", p)
    cur_mask = mask
    post_mask = None
    audit_fails = 0
    while True:
        try:
            y, ok = _spmspv_2d(a, x, sr, mesh=mesh, variant=p.variant,
                               merge=p.merge, prod_cap=p.prod_cap,
                               out_cap=p.out_cap, mask=cur_mask)
        except _audit.AuditError as err:
            audit_fails += 1
            timeout = isinstance(err, _deadline.ExchangeTimeout)
            _obs.event("plan.audit_retry", op="spmspv", site=err.site,
                       attempt=p.attempts, fails=audit_fails,
                       timeout=timeout)
            _obs.counter_add("plan.audit_retries")
            if audit_fails <= MAX_AUDIT_RETRIES:
                warnings.warn(
                    f"SpMSpV attempt {p.attempts} failed audit at "
                    f"{err.site}: {err} — retrying from pristine inputs "
                    f"({audit_fails}/{MAX_AUDIT_RETRIES})",
                    RuntimeWarning, stacklevel=2)
                if timeout:
                    _deadline.backoff_sleep(err.site, audit_fails)
                p = dataclasses.replace(p, attempts=p.attempts + 1)
                continue
            rung = _recover.next_rung(p, cur_mask, kind="spmspv")
            if rung is None:
                if timeout:
                    raise _deadline.TopologyError(
                        f"SpMSpV exchange at {err.site} still over deadline "
                        f"after {p.attempts} attempts with the degradation "
                        f"ladder exhausted (degraded={p.degraded})",
                        err.site) from err
                raise
            p = _recover.apply_rung(rung, p)
            if timeout:
                _deadline.reset(err.site)
            p, cur_mask, post_mask = _spmspv_take_rung(
                rung, p, a, x, safety, sr, cur_mask, post_mask)
            continue
        ok = _faults.flip_ok("plan.spmspv.ok", ok)
        if bool(jnp.all(ok)):
            if post_mask is not None:
                y = _recover.postfilter_spvec(y, post_mask)
            if p.attempts > 1 or p.degraded:
                _plan_event("plan.spmspv.done", p)
            return y, p
        if p.attempts < max_attempts and not p.at_ceiling():
            _obs.event("plan.overflow_retry", op="spmspv",
                       attempt=p.attempts, prod_cap=p.prod_cap,
                       out_cap=p.out_cap)
            _obs.counter_add("plan.overflow_retries")
            p = p.grown(growth)
            continue
        rung = _recover.next_rung(p, cur_mask, kind="spmspv")
        if rung is None:
            raise RuntimeError(
                f"SpMSpV still overflowing after {p.attempts} attempts "
                f"(prod_cap={p.prod_cap}, out_cap={p.out_cap}) — "
                f"degradation ladder exhausted (degraded={p.degraded})")
        p = _recover.apply_rung(rung, p)
        p, cur_mask, post_mask = _spmspv_take_rung(
            rung, p, a, x, safety, sr, cur_mask, post_mask)


def _spmspv_take_rung(rung, p, a, x, safety, sr, cur_mask, post_mask):
    """SpMSpV counterpart of ``_spgemm_take_rung``: dropping the mask
    invalidates the mask-capped output sizing — re-plan unmasked."""
    p = dataclasses.replace(p, attempts=p.attempts + 1)
    if rung != "postfilter":
        return p, cur_mask, post_mask
    fresh = plan_spmspv(a, int(jax.device_get(jnp.sum(x.nnz))),
                        safety=safety, variant=p.variant, merge=p.merge,
                        add_tag=sr.add.tag)
    p = dataclasses.replace(
        p,
        prod_cap=max(p.prod_cap, fresh.prod_cap),
        out_cap=max(p.out_cap, fresh.out_cap),
        prod_ceiling=max(p.prod_ceiling, fresh.prod_ceiling),
        out_ceiling=max(p.out_ceiling, fresh.out_ceiling))
    return p, None, cur_mask


def spmv_variant(a: DistSpMat) -> str:
    """Local SpMV flavor whose required sort order the tile already has.

    Row-partitioned SpMV wants row-major tiles, col-partitioned wants
    col-major (§4.2); matching the maintained order tag makes the kernel's
    sort a no-op.
    """
    return "col" if a.order == "col" else "row"


# --------------------------------------------------------------------------
# local (single-tile) planning — benchmarks and non-distributed callers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LocalSpGEMMPlan:
    prod_cap: int
    out_cap: int
    algo: str              # 'esc' | 'dense'
    flops: int             # exact symbolic count
    ratio: float           # estimated compression ratio


def plan_local_spgemm(a: COO, b: COO, *, safety: float = 1.25,
                      dense_threshold: float = 4.0,
                      dense_tile_limit: int = 1 << 22,
                      mask_nnz: int | None = None) -> LocalSpGEMMPlan:
    """Exact symbolic phase for one tile pair (paper §4.1 phase 1).

    ``spgemm_flops`` is exact, so ``prod_cap`` cannot overflow; ``out_cap``
    is bounded by min(flops, dense tile) — and by ``mask_nnz`` when the
    caller multiplies under a structural mask (the masked output pattern is
    a subset of the mask, §4.7). The algo pick mirrors ``spgemm_auto``'s
    compression-ratio hybrid.
    """
    m, n = a.shape[0], b.shape[1]
    fl = int(jax.device_get(spgemm_flops(a, b)))
    ratio = float(jax.device_get(compression_ratio(a, b)))
    prod_cap = _pow2(max(fl, 1) * safety)
    out_bound = min(max(fl, 1), m * n)
    if mask_nnz is not None:
        out_bound = min(out_bound, max(int(mask_nnz), 1))
    out_cap = min(_pow2(out_bound * safety), _pow2(m * n))
    algo = "dense" if (ratio >= dense_threshold and m * n <= dense_tile_limit) \
        else "esc"
    return LocalSpGEMMPlan(prod_cap, out_cap, algo, fl, ratio)


@dataclasses.dataclass(frozen=True)
class LocalSpMSpVPlan:
    prod_cap: int
    out_cap: int
    variant: str
    use_spmv: bool
    density: float


def plan_local_spmspv(a: COO, frontier_nnz: int, *,
                      safety: float = 4.0) -> LocalSpMSpVPlan:
    n = max(a.shape[1], 1)
    density = max(int(frontier_nnz), 1) / n
    nnz = int(jax.device_get(a.nnz))
    est = max(nnz * density, 1.0)
    prod_cap = min(_pow2(safety * est), _pow2(max(nnz, 1)))
    out_cap = min(_pow2(safety * est), _pow2(a.shape[0]))
    return LocalSpMSpVPlan(prod_cap, out_cap,
                           spmspv_variant_for_density(density),
                           density > 0.30, density)
