"""repro.core — CombBLAS 2.0 primitives in JAX (the paper's contribution).

Layering:
  semiring      generalized (add, mul) algebra + segment reductions
  coo           capacity-padded local sparse tiles (SpMat analogue)
  merge         sort-free merge engine: packed-key dedup, rank-placement
                merging, kv stage pipeline (§4.4)
  local_spgemm  ESC / dense-accumulator / hybrid local multiply (§4.1)
  spmv_local    SpMV + SpMSpV variant families (§4.2–4.3)
  dist          SpParMat / FullyDist[Sp]Vec containers (§2.1–2.2)
  mask          output masks (GraphBLAS C⟨M⟩) + membership probes (§4.7)
  spgemm        2D SUMMA (rotation/allgather) + 3D CA SpGEMM (§3.2)
  spmv          distributed SpMV / SpMSpV (§3.1)
  spmm          1.5D + true-2D SpMM
  assign        skew-aware vector assign / extract (§3.3)
  plan          capacity planner + variant rules of thumb (§5, §7)
  compat        jax version shims (single home for post-0.4.x APIs)
"""
from . import compat, merge, semiring
from .coo import COO, SENTINEL, column_range, ewise_intersect, ewise_union
from .merge import (dedup_sorted, merge_capped, merge_sorted, merge_tree,
                    pack_keys)
from .dist import (DistSpMat, DistSpMat3D, DistSpVec, DistVec, make_grid,
                   shard_put, specs_of)
from .local_spgemm import (compression_ratio, spgemm_auto, spgemm_dense,
                           spgemm_esc, spgemm_flops)
from .mask import (LocalMask, MaskSpec, complement_of, local_mask,
                   mask_member, structural, value_mask, vector_mask)
from .semiring import (ARITHMETIC, BOOLEAN, MAX_MIN, MAX_PLUS, MIN_PLUS,
                       MIN_SELECT2ND, Monoid, Semiring, segment_reduce,
                       semiring as make_semiring)
from .spgemm import spgemm_2d, spgemm_2d_batched, spgemm_3d
from .spmm import local_spmm, spmm_15d, spmm_2d
from .spmv import (spmspv, spmv, spmv_iter, transpose_layout,
                   transpose_spvec_layout)
from .spmv_local import (SPMSPV_VARIANTS, spmspv_auto, spmv_col, spmv_row,
                         spvec_from_dense, spvec_to_dense)
from .assign import assign, extract
from .plan import (LocalSpGEMMPlan, LocalSpMSpVPlan, SpGEMMPlan, SpMSpVPlan,
                   plan_local_spgemm, plan_local_spmspv, plan_spgemm,
                   plan_spmspv, spmspv_variant_for_density, spmv_variant)
from .plan import spgemm as spgemm_planned
from .plan import spmspv as spmspv_planned
