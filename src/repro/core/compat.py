"""JAX version-compat shims (single home for every post-0.4.x API we touch).

The repo targets the jax that ships in the container (0.4.37 today) while
staying forward-compatible with newer releases. Three surfaces moved between
0.4.x and 0.5+/0.6+ and are guarded here with ``getattr`` fallbacks:

  - ``jax.sharding.AxisType`` (and ``jax.make_mesh(axis_types=...)``):
    explicit-vs-auto axis types only exist on newer jax. On 0.4.x every mesh
    axis is implicitly "auto", so the kwarg is simply dropped.
  - ``jax.shard_map``: the public binding is new; 0.4.x has
    ``jax.experimental.shard_map.shard_map``. The experimental version also
    takes ``check_rep`` (replication checking) which we disable — our bodies
    use collectives whose replication typing predates the checker's rules.
  - ``jax.lax.pcast``: newer shard_map requires constants entering a scan
    carry to be cast to "varying"; on 0.4.x the concept does not exist and
    the identity is the correct behavior.

Everything else in core/ imports these names from here, never from jax
directly, so a jax upgrade is a one-file audit.
"""
from __future__ import annotations

import jax

AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with auto axis types when the installed jax has them."""
    kwargs = {}
    if AXIS_TYPE is not None:
        kwargs["axis_types"] = (AXIS_TYPE.Auto,) * len(tuple(axis_shapes))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices, **kwargs)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, check_rep=False)


def pvary(x, axes):
    """Cast a replicated value to "varying" over ``axes``.

    Modern jax spells it ``jax.lax.pvary``; some intermediate versions had
    ``jax.lax.pcast(..., to="varying")``; 0.4.x has neither and needs
    nothing (shard_map did not track varying-ness yet) — identity.
    """
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, axes)
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axes, to="varying")


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the TPUCompilerParams rename.

    Newer jax: ``pltpu.CompilerParams``; 0.4.x: ``pltpu.TPUCompilerParams``.
    Imported lazily so core/ never pays the pallas import cost.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
