"""Parallel Matrix Market I/O (paper §6, ParallelReadMM).

The paper's reader: processor p_i seeks to ``filesize·i/|P|``, fast-forwards
to the next newline, and parses until its end boundary, finishing any line
it started (the next reader skips its leading partial line). Writing: rank 0
emits the header; every rank serializes its local nonzeros to a byte stream
and the streams land at precomputed offsets (the collective MPI-IO pattern).

Here "processors" are reader workers (threads); the byte-range splitting
logic is identical to the MPI-IO version and is what the Table 5 benchmark
measures.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np


def read_mm_header(path: str):
    """Parse the MatrixMarket banner + size line."""
    with open(path, "rb") as f:
        banner = f.readline().decode()
        if not banner.startswith("%%MatrixMarket"):
            raise ValueError("not a MatrixMarket file")
        toks = banner.strip().split()
        field, symmetry = toks[3], toks[4]
        line = f.readline().decode()
        while line.startswith("%"):
            line = f.readline().decode()
        m, n, nnz = (int(t) for t in line.split())
        return dict(field=field, symmetry=symmetry, m=m, n=n, nnz=nnz,
                    body_offset=f.tell())


def _parse_text(text: str, pattern: bool):
    if not text.strip():
        return (np.empty(0, np.int64), np.empty(0, np.int64),
                np.empty(0, np.float64))
    width = 2 if pattern else 3
    d = np.array(text.split(), dtype=np.float64).reshape(-1, width)
    vals = np.ones(len(d), np.float64) if pattern else d[:, 2]
    return (d[:, 0].astype(np.int64) - 1, d[:, 1].astype(np.int64) - 1, vals)


def _read_chunk(path, start, end, body0, pattern):
    """Read complete lines whose start lies in [start, end)."""
    with open(path, "rb") as f:
        f.seek(start)
        if start > body0:
            f.readline()            # partial line owned by the predecessor
        pos = f.tell()
        if pos >= end:
            return _parse_text("", pattern)
        buf = f.read(end - pos)
        tail = f.readline()         # finish the straddling line
        if tail:
            buf += tail
    return _parse_text(buf.decode(), pattern)


def read_mm_parallel(path: str, nreaders: int = 4):
    """Parallel MatrixMarket read → (shape, rows, cols, vals) int64 global."""
    hdr = read_mm_header(path)
    size = os.path.getsize(path)
    body0 = hdr["body_offset"]
    pattern = hdr["field"] == "pattern"
    bounds = [body0 + (size - body0) * i // nreaders
              for i in range(nreaders + 1)]

    def work(i):
        return _read_chunk(path, bounds[i], bounds[i + 1], body0, pattern)

    if nreaders == 1:
        parts = [work(0)]
    else:
        with ThreadPoolExecutor(nreaders) as ex:
            parts = list(ex.map(work, range(nreaders)))
    rows = np.concatenate([p[0] for p in parts])
    cols = np.concatenate([p[1] for p in parts])
    vals = np.concatenate([p[2] for p in parts])
    if hdr["symmetry"] == "symmetric":
        off = rows != cols
        rows, cols, vals = (np.concatenate([rows, cols[off]]),
                            np.concatenate([cols, rows[off]]),
                            np.concatenate([vals, vals[off]]))
    return (hdr["m"], hdr["n"]), rows, cols, vals


def write_mm_parallel(path: str, shape, rows, cols, vals, nwriters: int = 4,
                      field: str = "real"):
    """Parallel MatrixMarket write (precomputed-offset collective pattern)."""
    m, n = shape
    nnz = len(rows)
    header = (f"%%MatrixMarket matrix coordinate {field} general\n"
              f"{m}\t{n}\t{nnz}\n").encode()
    slices = [slice(nnz * i // nwriters, nnz * (i + 1) // nwriters)
              for i in range(nwriters)]

    def serialize(i):
        s = slices[i]
        if field == "pattern":
            lines = [f"{r + 1}\t{c + 1}\n" for r, c in zip(rows[s], cols[s])]
        else:
            lines = [f"{r + 1}\t{c + 1}\t{v:.10g}\n"
                     for r, c, v in zip(rows[s], cols[s], vals[s])]
        return "".join(lines).encode()

    with ThreadPoolExecutor(nwriters) as ex:
        streams = list(ex.map(serialize, range(nwriters)))
    offsets = [len(header)]
    for st in streams[:-1]:
        offsets.append(offsets[-1] + len(st))
    with open(path, "wb") as f:
        f.write(header)
        f.truncate(offsets[-1] + len(streams[-1]))

    def put(i):
        with open(path, "r+b") as f:
            f.seek(offsets[i])
            f.write(streams[i])

    with ThreadPoolExecutor(nwriters) as ex:
        list(ex.map(put, range(nwriters)))
