"""Parallel Matrix Market I/O (paper §6, ParallelReadMM).

The paper's reader: processor p_i seeks to ``filesize·i/|P|``, fast-forwards
to the next newline, and parses until its end boundary, finishing any line
it started (the next reader skips its leading partial line). Writing: rank 0
emits the header; every rank serializes its local nonzeros to a byte stream
and the streams land at precomputed offsets (the collective MPI-IO pattern).

Here "processors" are reader workers (threads); the byte-range splitting
logic is identical to the MPI-IO version and is what the Table 5 benchmark
measures.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..obs import recorder as _obs
from ..robust import faults as _faults


def read_mm_header(path: str):
    """Parse the MatrixMarket banner + size line.

    Malformed input raises ValueError naming the file and byte offset —
    never an IndexError from a short banner or a bare int() traceback.
    """
    with open(path, "rb") as f:
        banner = f.readline().decode(errors="replace")
        if not banner.startswith("%%MatrixMarket"):
            raise ValueError(f"{path}: not a MatrixMarket file "
                             f"(banner {banner[:40]!r} at offset 0)")
        toks = banner.strip().split()
        if len(toks) < 5:
            raise ValueError(
                f"{path}: malformed MatrixMarket banner at offset 0 — "
                f"want '%%MatrixMarket matrix coordinate <field> "
                f"<symmetry>', got {banner.strip()!r}")
        if toks[1] != "matrix" or toks[2] != "coordinate":
            raise ValueError(
                f"{path}: unsupported MatrixMarket object/format "
                f"{toks[1]!r}/{toks[2]!r} (only 'matrix coordinate')")
        field, symmetry = toks[3], toks[4]
        off = f.tell()
        line = f.readline().decode(errors="replace")
        while line.startswith("%"):
            off = f.tell()
            line = f.readline().decode(errors="replace")
        try:
            m, n, nnz = (int(t) for t in line.split())
        except ValueError:
            raise ValueError(
                f"{path}: bad size line at offset {off} — want "
                f"'<rows> <cols> <nnz>', got {line.strip()!r}") from None
        if m < 0 or n < 0 or nnz < 0:
            raise ValueError(f"{path}: negative dimension in size line at "
                             f"offset {off}: {line.strip()!r}")
        return dict(field=field, symmetry=symmetry, m=m, n=n, nnz=nnz,
                    body_offset=f.tell())


def _parse_text(text: str, pattern: bool, *, path: str = "?",
                offset: int = 0):
    if not text.strip():
        return (np.empty(0, np.int64), np.empty(0, np.int64),
                np.empty(0, np.float64))
    width = 2 if pattern else 3
    toks = text.split()
    try:
        flat = np.array(toks, dtype=np.float64)
    except ValueError:
        raise ValueError(
            f"{path}: non-numeric token in coordinate body near offset "
            f"{offset}") from None
    if len(flat) % width:
        raise ValueError(
            f"{path}: truncated/malformed coordinate body near offset "
            f"{offset} — {len(flat)} tokens is not a multiple of the "
            f"{width}-token entry width")
    d = flat.reshape(-1, width)
    vals = np.ones(len(d), np.float64) if pattern else d[:, 2]
    return (d[:, 0].astype(np.int64) - 1, d[:, 1].astype(np.int64) - 1, vals)


def _read_chunk(path, start, end, body0, pattern):
    """Read complete lines whose start lies in [start, end)."""
    with open(path, "rb") as f:
        f.seek(start)
        if start > body0:
            f.readline()            # partial line owned by the predecessor
        pos = f.tell()
        if pos >= end:
            return _parse_text("", pattern, path=path, offset=pos)
        buf = f.read(end - pos)
        tail = f.readline()         # finish the straddling line
        if tail:
            buf += tail
    buf = _faults.corrupt_bytes("io.mm_body", buf)
    return _parse_text(buf.decode(errors="replace"), pattern,
                       path=path, offset=pos)


@_obs.timed("io.read_mm")
def read_mm_parallel(path: str, nreaders: int = 4):
    """Parallel MatrixMarket read → (shape, rows, cols, vals) int64 global."""
    hdr = read_mm_header(path)
    size = os.path.getsize(path)
    _obs.counter_add("io.bytes_read", size)
    body0 = hdr["body_offset"]
    pattern = hdr["field"] == "pattern"
    bounds = [body0 + (size - body0) * i // nreaders
              for i in range(nreaders + 1)]

    def work(i):
        return _read_chunk(path, bounds[i], bounds[i + 1], body0, pattern)

    if nreaders == 1:
        parts = [work(0)]
    else:
        with ThreadPoolExecutor(nreaders) as ex:
            parts = list(ex.map(work, range(nreaders)))
    rows = np.concatenate([p[0] for p in parts])
    cols = np.concatenate([p[1] for p in parts])
    vals = np.concatenate([p[2] for p in parts])
    # pre-expansion entry count must match the header (symmetric expansion
    # below legitimately adds entries) — a truncated body fails here loudly
    if len(rows) != hdr["nnz"]:
        raise ValueError(
            f"{path}: body holds {len(rows)} entries but the size line at "
            f"offset {body0} promised {hdr['nnz']} — truncated or "
            "corrupted file")
    if hdr["symmetry"] == "symmetric":
        off = rows != cols
        rows, cols, vals = (np.concatenate([rows, cols[off]]),
                            np.concatenate([cols, rows[off]]),
                            np.concatenate([vals, vals[off]]))
    return (hdr["m"], hdr["n"]), rows, cols, vals


@_obs.timed("io.write_mm")
def write_mm_parallel(path: str, shape, rows, cols, vals, nwriters: int = 4,
                      field: str = "real"):
    """Parallel MatrixMarket write (precomputed-offset collective pattern)."""
    m, n = shape
    nnz = len(rows)
    header = (f"%%MatrixMarket matrix coordinate {field} general\n"
              f"{m}\t{n}\t{nnz}\n").encode()
    slices = [slice(nnz * i // nwriters, nnz * (i + 1) // nwriters)
              for i in range(nwriters)]

    def serialize(i):
        s = slices[i]
        if field == "pattern":
            lines = [f"{r + 1}\t{c + 1}\n" for r, c in zip(rows[s], cols[s])]
        else:
            lines = [f"{r + 1}\t{c + 1}\t{v:.10g}\n"
                     for r, c, v in zip(rows[s], cols[s], vals[s])]
        return "".join(lines).encode()

    with ThreadPoolExecutor(nwriters) as ex:
        streams = list(ex.map(serialize, range(nwriters)))
    offsets = [len(header)]
    for st in streams[:-1]:
        offsets.append(offsets[-1] + len(st))
    with open(path, "wb") as f:
        f.write(header)
        f.truncate(offsets[-1] + len(streams[-1]))

    def put(i):
        with open(path, "r+b") as f:
            f.seek(offsets[i])
            f.write(streams[i])

    with ThreadPoolExecutor(nwriters) as ex:
        list(ex.map(put, range(nwriters)))
    _obs.counter_add("io.bytes_written", os.path.getsize(path))
