"""CombBLAS-style proprietary binary format (paper §6).

Layout: 32-byte header (magic, version, m, n, nnz, value dtype code) followed
by contiguous int64 rows, int64 cols, and values. Reads/writes are
memory-mapped and chunked across workers — the binary baseline for the
Table 5 I/O benchmark.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..obs import recorder as _obs
from ..robust import faults as _faults

MAGIC = 0x434242494F31      # "CBBIO1"
_DTYPES = {0: np.float64, 1: np.float32, 2: np.int64, 3: np.int32}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}
_HDR_BYTES = 48             # 6 × int64


@_obs.timed("io.write_bin")
def write_binary(path: str, shape, rows, cols, vals, nwriters: int = 4):
    m, n = shape
    nnz = len(rows)
    vals = np.asarray(vals)
    code = _CODES[vals.dtype]
    header = np.array([MAGIC, 1, m, n, nnz, code], np.int64)
    rows64 = np.asarray(rows, np.int64)
    cols64 = np.asarray(cols, np.int64)
    with open(path, "wb") as f:
        f.write(header.tobytes())
        total = nnz * (16 + vals.itemsize)
        f.truncate(48 + total)
    mm = np.memmap(path, np.uint8, "r+", offset=48)
    r_view = mm[: nnz * 8].view(np.int64)
    c_view = mm[nnz * 8: nnz * 16].view(np.int64)
    v_view = mm[nnz * 16:].view(vals.dtype)

    def put(i):
        s = slice(nnz * i // nwriters, nnz * (i + 1) // nwriters)
        r_view[s] = rows64[s]
        c_view[s] = cols64[s]
        v_view[s] = vals[s]

    with ThreadPoolExecutor(nwriters) as ex:
        list(ex.map(put, range(nwriters)))
    mm.flush()
    _obs.counter_add("io.bytes_written", os.path.getsize(path))
    _faults.corrupt_file("io.bin_body", path)


@_obs.timed("io.read_bin")
def read_binary(path: str, nreaders: int = 4):
    """Read a CBBIO1 file; malformed/truncated input raises ValueError
    naming the file and byte offset — never an IndexError, KeyError, or a
    memmap crash on garbage sizes."""
    fsize = os.path.getsize(path)
    _obs.counter_add("io.bytes_read", fsize)
    if fsize < _HDR_BYTES:
        raise ValueError(f"{path}: truncated header — file is {fsize} bytes, "
                         f"need {_HDR_BYTES} (offset 0)")
    header = np.fromfile(path, np.int64, 6)
    if header[0] != MAGIC:
        raise ValueError(f"{path}: bad magic {int(header[0]):#x} at offset 0 "
                         f"(want {MAGIC:#x})")
    _, _, m, n, nnz, code = (int(x) for x in header)
    if code not in _DTYPES:
        raise ValueError(f"{path}: unknown value dtype code {code} at "
                         f"offset 40")
    if m < 0 or n < 0 or nnz < 0:
        raise ValueError(f"{path}: negative dimension in header "
                         f"(m={m}, n={n}, nnz={nnz})")
    dtype = _DTYPES[code]
    expected = _HDR_BYTES + nnz * (16 + np.dtype(dtype).itemsize)
    if fsize < expected:
        raise ValueError(
            f"{path}: truncated body — header promises {nnz} entries "
            f"({expected} bytes) but file is {fsize} bytes "
            f"(body starts at offset {_HDR_BYTES})")
    mm = np.memmap(path, np.uint8, "r", offset=_HDR_BYTES)
    rows = np.empty(nnz, np.int64)
    cols = np.empty(nnz, np.int64)
    vals = np.empty(nnz, dtype)

    def get(i):
        s = slice(nnz * i // nreaders, nnz * (i + 1) // nreaders)
        rows[s] = mm[: nnz * 8].view(np.int64)[s]
        cols[s] = mm[nnz * 8: nnz * 16].view(np.int64)[s]
        vals[s] = mm[nnz * 16:].view(dtype)[s]

    with ThreadPoolExecutor(nreaders) as ex:
        list(ex.map(get, range(nreaders)))
    return (m, n), rows, cols, vals
