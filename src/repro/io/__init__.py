"""repro.io — parallel I/O (paper §6) and graph generators."""
from .mmio import read_mm_parallel, write_mm_parallel, read_mm_header
from .labelio import read_generalized_tuples
from .binio import read_binary, write_binary
from .rmat import rmat_edges, rmat_coo
