"""Label-format reader — ReadGeneralizedTuples (paper §6).

The MCL label format: no header; each line is ``src dst [weight]`` where
src/dst are *arbitrary string labels* (scattered integers, DNA sequences…).
The paper's two-pass algorithm, reproduced with the same communication
structure ("processors" = workers, the all-to-all = bucket exchange):

  pass 1: every worker hashes its labels into {0..max}; the hash range is
          partitioned into |P| buckets; an all-to-all sends (label, hash) to
          the bucket owner; owners dedup with a local set, compute their
          count, and an exclusive scan over owner counts assigns each label
          a unique consecutive id; owners answer each sender with the new
          ids (the reverse all-to-all).
  pass 2: workers re-read their byte range and relabel streaming.

Returned ids are assigned in hash-bucket order ⇒ the relabeling *is* a
random permutation of the vertex space: the load-balance side effect the
paper highlights (one can use this reader in lieu of ParallelReadMM +
explicit permutation).

Returns (shape, rows, cols, vals, labels) where labels[i] is the original
string of vertex i — the paper's "CombBLAS compliant distributed vector"
mapping new ids back to labels.
"""
from __future__ import annotations

import hashlib
import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..obs import recorder as _obs


def _hash_label(label: bytes, space: int = 2**61 - 1) -> int:
    return int.from_bytes(hashlib.blake2b(label, digest_size=8).digest(),
                          "little") % space


def _byte_ranges(path, nworkers):
    size = os.path.getsize(path)
    return [(size * i // nworkers, size * (i + 1) // nworkers)
            for i in range(nworkers)]


def _read_lines(path, start, end):
    with open(path, "rb") as f:
        f.seek(start)
        if start > 0:
            f.readline()
        pos = f.tell()
        if pos >= end:
            return []
        buf = f.read(end - pos)
        tail = f.readline()
        if tail:
            buf += tail
    return [ln for ln in buf.split(b"\n") if ln.strip()]


@_obs.timed("io.read_tuples")
def read_generalized_tuples(path: str, nworkers: int = 4, weighted=None):
    """Two-pass parallel label-format reader. See module docstring."""
    _obs.counter_add("io.bytes_read", os.path.getsize(path))
    ranges = _byte_ranges(path, nworkers)

    # ---------------- pass 1: label discovery -------------------------
    def collect(i):
        labels = set()
        for ln in _read_lines(path, *ranges[i]):
            parts = ln.split()
            labels.add(parts[0])
            labels.add(parts[1])
        return labels

    with ThreadPoolExecutor(nworkers) as ex:
        worker_labels = list(ex.map(collect, range(nworkers)))

    # bucket exchange: hash space partitioned into |P| buckets
    space = 2**61 - 1
    buckets: list[set] = [set() for _ in range(nworkers)]
    for labels in worker_labels:                  # the all-to-all
        for lb in labels:
            h = _hash_label(lb, space)
            buckets[h * nworkers // space].add((h, lb))

    # owners dedup (the set is the dedup) and get id ranges via ex-scan
    counts = [len(b) for b in buckets]
    starts = np.zeros(nworkers + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    label_to_id: dict[bytes, int] = {}
    id_to_label: list[bytes] = [b""] * int(starts[-1])
    for bi, b in enumerate(buckets):
        # sort by hash within bucket -> ids are hash-ordered = pseudorandom
        for off, (h, lb) in enumerate(sorted(b)):
            new_id = int(starts[bi]) + off
            label_to_id[lb] = new_id              # the reverse all-to-all
            id_to_label[new_id] = lb
    nvert = int(starts[-1])

    # ---------------- pass 2: streaming relabel -----------------------
    def relabel(i):
        rs, cs, vs = [], [], []
        for ln in _read_lines(path, *ranges[i]):
            parts = ln.split()
            rs.append(label_to_id[parts[0]])
            cs.append(label_to_id[parts[1]])
            vs.append(float(parts[2]) if len(parts) > 2 else 1.0)
        return (np.asarray(rs, np.int64), np.asarray(cs, np.int64),
                np.asarray(vs, np.float64))

    with ThreadPoolExecutor(nworkers) as ex:
        parts = list(ex.map(relabel, range(nworkers)))
    rows = np.concatenate([p[0] for p in parts]) if parts else \
        np.empty(0, np.int64)
    cols = np.concatenate([p[1] for p in parts])
    vals = np.concatenate([p[2] for p in parts])
    labels = [lb.decode() for lb in id_to_label]
    return (nvert, nvert), rows, cols, vals, labels
