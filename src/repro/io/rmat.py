"""R-MAT / Graph500 generator (paper §4.5 uses it for the Fig-3 sweep).

Vectorized recursive quadrant sampling in numpy with the Graph500
parameters (a, b, c, d) = (0.57, 0.19, 0.19, 0.05). Deterministic per seed.
"""
from __future__ import annotations

import numpy as np

GRAPH500 = (0.57, 0.19, 0.19, 0.05)


def rmat_edges(scale: int, edge_factor: int = 16, seed: int = 0,
               params=GRAPH500, permute: bool = True):
    """Generate 2^scale-vertex R-MAT edges. Returns (rows, cols) int64."""
    a, b, c, d = params
    n = 1 << scale
    ne = n * edge_factor
    rng = np.random.default_rng(seed)
    rows = np.zeros(ne, np.int64)
    cols = np.zeros(ne, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(ne)
        go_right = (r >= a) & (r < ab) | (r >= abc)
        go_down = r >= ab
        rows = (rows << 1) | go_down
        cols = (cols << 1) | go_right
    if permute:
        perm = rng.permutation(n).astype(np.int64)
        rows, cols = perm[rows], perm[cols]
    return rows, cols


def rmat_coo(scale: int, edge_factor: int = 16, seed: int = 0,
             params=GRAPH500, symmetrize: bool = False,
             drop_self_loops: bool = False):
    """R-MAT as deduplicated COO with unit weights."""
    rows, cols = rmat_edges(scale, edge_factor, seed, params)
    if symmetrize:
        rows, cols = (np.concatenate([rows, cols]),
                      np.concatenate([cols, rows]))
    if drop_self_loops:
        keep = rows != cols
        rows, cols = rows[keep], cols[keep]
    n = 1 << scale
    key = rows * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = np.ones(len(rows), np.float32)
    return (n, n), rows, cols, vals
