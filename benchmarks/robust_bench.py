"""Elastic-recovery benchmarks: what a topology fault actually costs.

Rows (all host-measured, deterministic seeds):

  robust_detect_deadline_us       time from a hung guarded exchange to the
                                  ExchangeTimeout raise (budget 1 ms, hang
                                  10 ms -> detection tracks the hang, not
                                  the 6-hour CI timeout)
  robust_backoff_total_us         the full deterministic 3-retry backoff
                                  schedule for one site (what a transient
                                  straggler adds end-to-end)
  robust_regrid_4x4_to_2x2_us     live DistSpMat.regrid onto the smaller
                                  grid (the in-process shrink primitive)
  robust_ckpt_save_us             save_spmat through the CRC-manifest path
  robust_ckpt_restore_shrink_us   restore_spmat onto a 2x smaller grid
                                  (the crash-and-shrink resume primitive)
  robust_steps_lost_crash_resume  iterations redone after a hard crash with
                                  every=2 checkpointing (derived column);
                                  µs is the redo cost at resume
  robust_recovery_overhead_ratio  faulted spgemm (1 deadline trip + retry)
                                  over clean spgemm wall time
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time
import warnings

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _matrix(n=4096, nnz=40000, seed=0, grid=(4, 4)):
    from repro.core import DistSpMat
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, nnz).astype(np.int64)
    c = rng.integers(0, n, nnz).astype(np.int64)
    v = rng.standard_normal(nnz).astype(np.float32)
    return DistSpMat.from_global_coo((n, n), r, c, v, grid)


def run(quick: bool = True):
    from repro.core import ARITHMETIC, DistSpMat, make_grid
    from repro.core.dist import restore_spmat, save_spmat
    from repro.core.plan import spgemm as spgemm_planned
    from repro.robust import deadline, faults
    from repro.robust.deadline import ExchangeGuard, ExchangeTimeout
    from repro.robust.recover import CheckpointedLoop, TopologyError

    rows = []
    reps = 3 if quick else 10

    # -- time-to-detect: hung exchange vs wall-time deadline ---------------
    g = ExchangeGuard(startup_deadline=0.001)
    det = []
    for _ in range(reps):
        t0 = time.perf_counter()
        try:
            with g.watch("bench.hang"):
                time.sleep(0.010)           # the hang
        except ExchangeTimeout:
            det.append((time.perf_counter() - t0) * 1e6)
    rows.append(("robust_detect_deadline_us", float(np.median(det)),
                 "hang=10ms,budget=1ms"))

    # -- deterministic backoff schedule ------------------------------------
    g = ExchangeGuard(backoff_base=0.05, backoff_cap=5.0)
    total = sum(g.backoff_delay("bench.site", a) for a in (1, 2, 3))
    rows.append(("robust_backoff_total_us", total * 1e6,
                 "3 retries, base=50ms"))

    # -- live regrid (the in-process shrink primitive) ---------------------
    m = _matrix()
    t0 = time.perf_counter()
    for _ in range(reps):
        m2 = m.regrid((2, 2))
    rows.append(("robust_regrid_4x4_to_2x2_us",
                 (time.perf_counter() - t0) / reps * 1e6,
                 f"n=4096,nnz=40000 -> cap={m2.cap}"))

    # -- mesh-independent sparse checkpoint save/restore -------------------
    tmp = tempfile.mkdtemp(prefix="robust_bench_")
    try:
        t0 = time.perf_counter()
        for i in range(reps):
            save_spmat(tmp, i, m)
        save_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(("robust_ckpt_save_us", save_us, "CRC manifest"))
        t0 = time.perf_counter()
        for _ in range(reps):
            m3, _ = restore_spmat(tmp, (2, 2))
        rows.append(("robust_ckpt_restore_shrink_us",
                     (time.perf_counter() - t0) / reps * 1e6,
                     "restore 4x4 ckpt onto 2x2"))
        assert np.array_equal(m3.to_dense(), m.to_dense())
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- steps lost across a hard crash (every=2 checkpointing) ------------
    tmp = tempfile.mkdtemp(prefix="robust_bench_loop_")
    ran = []

    def body(it, state):
        ran.append(it)
        return {"x": np.asarray(state["x"]) + 1}, False
    try:
        with faults.inject("loop.device_loss:crash:at=6"):
            try:
                CheckpointedLoop(tmp, every=2).run({"x": np.int64(0)},
                                                   body, 10)
            except TopologyError:
                pass
        crashed_after = len(ran)
        t0 = time.perf_counter()
        CheckpointedLoop(tmp, every=2).run({"x": np.int64(0)}, body, 10)
        redo_us = (time.perf_counter() - t0) * 1e6
        # TopologyError checkpoints the pre-crash state at the boundary, so
        # the only repeated work is the interrupted iteration itself
        lost = crashed_after + (len(ran) - crashed_after) - 10
        rows.append(("robust_steps_lost_crash_resume", redo_us,
                     f"steps_lost={lost}"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- end-to-end recovery overhead on a planned multiply ----------------
    mesh = make_grid(1, 1)
    rng = np.random.default_rng(1)
    n = 128 if quick else 512
    dense = (rng.random((n, n)) < 0.05).astype(np.float32)
    r, c = np.nonzero(dense)
    A = DistSpMat.from_global_coo((n, n), r.astype(np.int64),
                                  c.astype(np.int64), dense[r, c], (1, 1),
                                  mesh=mesh)
    spgemm_planned(A, A, ARITHMETIC, mesh=mesh)      # warm the caches
    t0 = time.perf_counter()
    spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
    clean = time.perf_counter() - t0
    from repro import obs
    obs.enable()
    ctr0 = dict(obs.counters())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with deadline.configure(startup_deadline=0.005,
                                backoff_base=0.002) as guard:
            with faults.inject(
                    "dist.exchange_deadline:delay:amount=0.02,count=1"):
                t0 = time.perf_counter()
                _, fpl = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
                faulted = time.perf_counter() - t0
            trips = sum(guard.stats(s)["trips"] for s in guard.sites())
    ctr = obs.counters()
    rows.append(("robust_recovery_overhead_ratio", faulted / max(clean, 1e-9),
                 f"clean={clean * 1e6:.0f}us faulted={faulted * 1e6:.0f}us"))
    # flight-recorder view of the same event (satellite rows: the ladder /
    # retry / audit state lands in BENCH_robust.json, not just stderr)
    rows.append(("robust_faulted_attempts", float(fpl.attempts),
                 "degraded=" + (",".join(fpl.degraded) or "none")))
    rows.append(("robust_deadline_trips", float(trips),
                 "guard.stats() across the faulted spgemm"))
    rows.append(("robust_audit_failures",
                 float(ctr.get("audit.failures", 0)
                       - ctr0.get("audit.failures", 0)),
                 "obs counter delta (faulted spgemm)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick="--full" not in sys.argv):
        print(f"{name},{us:.1f},{derived}")
    from repro import obs
    if obs.enabled():
        import json
        print("# trace_summary=" + json.dumps(obs.snapshot()))
