"""§4.1 reproduction: hash-vs-heap analogue — dense-accumulator vs ESC
local SpGEMM across compression ratios (paper: heap wins at LOW compression
ratio, hash at HIGH; our TPU mapping: ESC-sort ↔ heap, dense tile ↔ hash).

Capacities and the algo pick come from the planner's exact symbolic phase
(core/plan.py, plan_local_spgemm) instead of ad-hoc constants, and the
sweep additionally times the order-tag fast path (row-sorted tiles skip the
expansion sort) against the untagged fallback.

Merge-engine sweep (DESIGN.md §4.4): q SUMMA-stage expansion buffers at
planner-default capacities (safety ×4, pow2 quantization — the caps a real
2D deferred multiply runs with), merged by

  - the seed path: concatenate all q padded buffers, two-key value-carrying
    lax.sort, segmented reduce ("legacy concat-and-sort"), vs
  - the engine:   per-stage windowed compaction (cap-slack windows skip
    their sort at runtime) + pairwise rank-placement merge tree.

``spgemm_merge_engine_speedup`` is the headline ratio (target ≥ 1.5x);
``BENCH_spgemm.json`` (benchmarks/run.py --json) records the trajectory.

Masked sweep (§4.7): fused masked SpGEMM (mask probed before every stage
compaction, mask-sized caps) vs the unmasked-then-postfilter pipeline on
the triangle-counting shape — ``spgemm_masked_speedup`` targets ≥ 1.3x and
is gated by the CI bench-smoke job.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARITHMETIC
from repro.core.coo import COO, SENTINEL, ewise_intersect
from repro.core import merge as merge_engine
from repro.core.local_spgemm import _expand, spgemm_dense, spgemm_esc, \
    spgemm_flops
from repro.core.mask import local_mask
from repro.core.plan import MASK_PUSHDOWN_RATIO, plan_local_spgemm, _pow2
from repro.io import rmat_coo


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _col_slab(co: COO, lo: int, hi: int, axis: str) -> COO:
    """Restrict a tile to a column ('col') or row ('row') slab, compacted."""
    keep = ((np.asarray(co.col) >= lo) & (np.asarray(co.col) < hi)) \
        if axis == "col" else \
        ((np.asarray(co.row) >= lo) & (np.asarray(co.row) < hi))
    idx = np.argsort(~keep, kind="stable")
    r = np.asarray(co.row)[idx].copy()
    c = np.asarray(co.col)[idx].copy()
    v = np.asarray(co.val)[idx].copy()
    nnz = int(keep.sum())
    r[nnz:] = SENTINEL
    c[nnz:] = SENTINEL
    v[nnz:] = 0
    return COO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v),
               jnp.asarray(nnz, jnp.int32), co.shape, "row")


def _summa_stage_buffers(scale: int, deg: int, q: int, seed: int = 1,
                         safety: float = 4.0):
    """q SUMMA-stage product buffers for an RMAT graph squared.

    Stage s multiplies the s-th column slab of A by the s-th row slab —
    exactly the local work sequence of a q-stage 2D SUMMA — with prod_cap /
    out_cap sized the way plan_spgemm sizes them (×safety, pow2).
    """
    shape, r, c, v = rmat_coo(scale, deg, seed=seed)
    n = shape[0]
    dense = np.zeros((n, n), np.float32)
    dense[r, c] += v
    A = COO.from_dense(jnp.asarray(dense), cap=_pow2(int((dense != 0).sum())))
    w = n // q
    pairs = [(_col_slab(A, s * w, (s + 1) * w, "col"),
              _col_slab(A, s * w, (s + 1) * w, "row")) for s in range(q)]
    max_fl = max(int(jax.device_get(spgemm_flops(x, y))) for x, y in pairs)
    prod_cap = _pow2(max_fl * safety)
    nnz_c = int((dense @ dense != 0).sum())
    out_cap = _pow2(nnz_c * 1.25)
    outs = [_expand(x, y, ARITHMETIC, prod_cap) for x, y in pairs]
    stages = [(o[0], o[1], o[2],
               jnp.minimum(o[3], prod_cap).astype(jnp.int32)) for o in outs]
    return stages, (n, n), prod_cap, out_cap


def merge_sweep(quick=True):
    """Merge-engine vs seed concat-and-sort on the deferred 2D path."""
    rows = []
    scale, q = 9, 8                   # default sizes (planner-default caps)
    reps = 2 if quick else 3
    stages, shape, prod_cap, out_cap = _summa_stage_buffers(scale, 8, q)
    stage_cap = min(prod_cap, out_cap)
    add = ARITHMETIC.add

    def legacy(st):
        r = jnp.concatenate([s[0] for s in st])
        c = jnp.concatenate([s[1] for s in st])
        v = jnp.concatenate([s[2] for s in st])
        total = sum(s[3] for s in st)
        prods = COO(r, c, v, jnp.minimum(total, r.shape[0]).astype(jnp.int32),
                    shape, "none")
        return merge_engine.dedup_legacy(prods, add, "row") \
            .with_cap(out_cap, 0)

    def engine(st):
        c, ok = merge_engine.merge_stage_products(st, shape, add, stage_cap,
                                                  out_cap)
        return c

    jl, je = jax.jit(legacy), jax.jit(engine)
    ref, got = jl(stages), je(stages)
    np.testing.assert_allclose(np.asarray(ref.to_dense()),
                               np.asarray(got.to_dense()),
                               rtol=1e-4, atol=1e-4)
    t_legacy = _time(jl, stages, reps=reps)
    t_engine = _time(je, stages, reps=reps)
    meta = f"q={q}_prodcap={prod_cap}_outcap={out_cap}"
    rows.append((f"spgemm_merge_legacy_sort_s{scale}", t_legacy, meta))
    rows.append((f"spgemm_merge_engine_deferred_s{scale}", t_engine, meta))
    rows.append((f"spgemm_merge_engine_speedup_s{scale}",
                 t_legacy / max(t_engine, 1e-9), "target>=1.5"))

    # packed-key dedup vs the seed two-key sort (one concat buffer)
    r = jnp.concatenate([s[0] for s in stages])
    c = jnp.concatenate([s[1] for s in stages])
    v = jnp.concatenate([s[2] for s in stages])
    total = sum(s[3] for s in stages)
    prods = COO(r, c, v, jnp.minimum(total, r.shape[0]).astype(jnp.int32),
                shape, "none")
    jp = jax.jit(lambda p: merge_engine.dedup(p, add, "row"))
    jg = jax.jit(lambda p: merge_engine.dedup_legacy(p, add, "row"))
    t_packed = _time(jp, prods, reps=reps)
    t_twokey = _time(jg, prods, reps=reps)
    rows.append(("dedup_packed_key", t_packed,
                 f"concat_cap={int(r.shape[0])}"))
    rows.append(("dedup_two_key_legacy", t_twokey, "seed implementation"))
    rows.append(("dedup_packed_speedup", t_twokey / max(t_packed, 1e-9),
                 "packed single-key vs two-key sort"))

    # sorted fast path: dedup of an already row-sorted tile skips the sort
    sorted_tile = jp(prods)                      # row-sorted, tagged
    js = jax.jit(lambda t: t.dedup_sorted(add))
    ju = jax.jit(lambda t: merge_engine.dedup(
        COO(t.row, t.col, t.val, t.nnz, t.shape, "none"), add, "row"))
    t_sorted = _time(js, sorted_tile, reps=reps)
    t_unsorted = _time(ju, sorted_tile, reps=reps)
    rows.append(("dedup_sorted_fast_path", t_sorted, "order-tag, no sort"))
    rows.append(("dedup_sorted_speedup", t_unsorted / max(t_sorted, 1e-9),
                 "vs untagged packed dedup"))
    return rows


def masked_sweep(quick=True):
    """Fused masked SpGEMM vs unmasked-then-postfilter (§4.7).

    Triangle-counting shape: L·L under the structural mask L (strict lower
    triangle of a symmetrized RMAT graph), through the same q-stage
    deferred merge pipeline a 2D SUMMA runs per device.

      - postfilter: merge at FULL L·L capacities, then ewise-intersect the
        materialized product with L (the seed apps/tricount.py pipeline);
      - fused:      every stage's expanded products are probed against L's
        packed keys before compaction, and stage/out caps come from the
        planner's mask-intersected bound (nnz(L), not nnz(L·L)).

    ``spgemm_masked_speedup`` is the acceptance ratio (target ≥ 1.3x); the
    CI bench-smoke job gates on these rows landing in BENCH_spgemm.json.
    """
    rows = []
    scale, q = 9, 8                   # default sizes (planner-default caps)
    reps = 2 if quick else 3
    shape, r, c, v = rmat_coo(scale, 8, seed=2)
    n = shape[0]
    dense = np.zeros((n, n), np.float32)
    dense[r, c] += v
    sym = ((dense + dense.T) != 0).astype(np.float32)
    low = np.tril(sym, -1)
    nnz_l = int((low != 0).sum())
    L = COO.from_dense(jnp.asarray(low), cap=_pow2(nnz_l))    # order='row'
    add = ARITHMETIC.add

    # q SUMMA-stage product buffers of L·L (stage s: col-slab × row-slab)
    w = n // q
    pairs = [(_col_slab(L, s * w, (s + 1) * w, "col"),
              _col_slab(L, s * w, (s + 1) * w, "row")) for s in range(q)]
    max_fl = max(int(jax.device_get(spgemm_flops(x, y))) for x, y in pairs)
    prod_cap = _pow2(max_fl * 4.0)
    nnz_c = int(((low @ low) != 0).sum())
    out_cap_full = _pow2(nnz_c * 1.25)          # unmasked L·L capacity
    out_cap_mask = _pow2(nnz_l * 1.25)          # planner mask bound: nnz(L)
    outs = [_expand(x, y, ARITHMETIC, prod_cap) for x, y in pairs]
    stages = [(o[0], o[1], o[2],
               jnp.minimum(o[3], prod_cap).astype(jnp.int32)) for o in outs]

    def postfilter(st, l):
        c, _ok = merge_engine.merge_stage_products(
            st, (n, n), add, min(prod_cap, out_cap_full), out_cap_full)
        return ewise_intersect(c, l, jnp.multiply, out_cap=out_cap_mask)

    def fused(st, l):
        c, _ok = merge_engine.merge_stage_products(
            st, (n, n), add, min(prod_cap, out_cap_mask), out_cap_mask,
            mask=local_mask(l))
        return c

    jp, jf = jax.jit(postfilter), jax.jit(fused)
    ref, got = jp(stages, L), jf(stages, L)
    np.testing.assert_allclose(np.asarray(ref.to_dense()),
                               np.asarray(got.to_dense()),
                               rtol=1e-4, atol=1e-4)
    t_post = _time(jp, stages, L, reps=reps)
    t_fused = _time(jf, stages, L, reps=reps)
    # the §4.6 rule of thumb: fused should win (clearly) when the mask
    # admits less than MASK_PUSHDOWN_RATIO of the unmasked output
    ratio = nnz_l / max(nnz_c, 1)
    meta = f"q={q}_masknnz={nnz_l}_outfull={out_cap_full}" \
           f"_outmask={out_cap_mask}_maskratio={ratio:.2f}" \
           f"_thresh={MASK_PUSHDOWN_RATIO}"
    rows.append((f"spgemm_masked_postfilter_s{scale}", t_post, meta))
    rows.append((f"spgemm_masked_fused_s{scale}", t_fused, meta))
    rows.append((f"spgemm_masked_speedup_s{scale}",
                 t_post / max(t_fused, 1e-9),
                 f"target>=1.3 (mask ratio {ratio:.2f} "
                 f"{'<' if ratio < MASK_PUSHDOWN_RATIO else '>='} "
                 f"{MASK_PUSHDOWN_RATIO} pushdown threshold)"))

    # single-tile fused masked ESC vs ESC + postfilter (informational)
    plan = plan_local_spgemm(L, L)
    plan_m = plan_local_spgemm(L, L, mask_nnz=nnz_l)
    esc_post = jax.jit(lambda a, l: ewise_intersect(
        spgemm_esc(a, a, ARITHMETIC, prod_cap=plan.prod_cap,
                   out_cap=plan.out_cap)[0],
        l, jnp.multiply, out_cap=plan_m.out_cap))
    esc_fused = jax.jit(lambda a, l: spgemm_esc(
        a, a, ARITHMETIC, prod_cap=plan_m.prod_cap, out_cap=plan_m.out_cap,
        mask=local_mask(l))[0])
    np.testing.assert_allclose(np.asarray(esc_post(L, L).to_dense()),
                               np.asarray(esc_fused(L, L).to_dense()),
                               rtol=1e-4, atol=1e-4)
    t_ep = _time(esc_post, L, L, reps=reps)
    t_ef = _time(esc_fused, L, L, reps=reps)
    rows.append((f"spgemm_esc_masked_postfilter_s{scale}", t_ep,
                 f"outcap={plan.out_cap}"))
    rows.append((f"spgemm_esc_masked_fused_s{scale}", t_ef,
                 f"outcap={plan_m.out_cap}"))
    rows.append((f"spgemm_esc_masked_speedup_s{scale}",
                 t_ep / max(t_ef, 1e-9), "single-tile ESC, informational"))
    return rows


def run(quick=True):
    rows = []
    rng = np.random.default_rng(0)
    n = 512
    densities = [0.002, 0.01, 0.05] if quick else \
        [0.001, 0.005, 0.02, 0.05, 0.1, 0.2]
    for d in densities:
        dense = np.where(rng.random((n, n)) < d,
                         rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
        nnz = int((dense != 0).sum())
        A = COO.from_dense(jnp.asarray(dense), cap=nnz + 8)   # order='row'
        A_untagged = COO(A.row, A.col, A.val, A.nnz, A.shape, "none")
        plan = plan_local_spgemm(A, A)
        esc = jax.jit(lambda a, b: spgemm_esc(
            a, b, ARITHMETIC, prod_cap=plan.prod_cap, out_cap=plan.out_cap))
        dns = jax.jit(lambda a, b: spgemm_dense(
            a, b, ARITHMETIC, out_cap=plan.out_cap))
        t_esc = _time(esc, A, A)                   # sorted fast path
        t_esc_untagged = _time(esc, A_untagged, A_untagged)  # seed path
        t_dns = _time(dns, A, A)
        rows.append((f"spgemm_esc_d{d}", t_esc, f"flops={plan.flops}"))
        rows.append((f"spgemm_esc_untagged_d{d}", t_esc_untagged,
                     "sort-fallback path"))
        rows.append((f"spgemm_sorted_speedup_d{d}",
                     t_esc_untagged / max(t_esc, 1e-9),
                     "untagged/tagged ratio (>=1 => fast path not slower)"))
        rows.append((f"spgemm_dense_d{d}", t_dns, f"cr={plan.ratio:.2f}"))
        rows.append((f"spgemm_planner_algo_d{d}",
                     t_dns if plan.algo == "dense" else t_esc, plan.algo))
        rows.append((f"spgemm_winner_d{d}", min(t_esc, t_dns),
                     "esc" if t_esc < t_dns else "dense"))
    rows.extend(merge_sweep(quick=quick))
    rows.extend(masked_sweep(quick=quick))
    return rows
