"""§4.1 reproduction: hash-vs-heap analogue — dense-accumulator vs ESC
local SpGEMM across compression ratios (paper: heap wins at LOW compression
ratio, hash at HIGH; our TPU mapping: ESC-sort ↔ heap, dense tile ↔ hash).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARITHMETIC
from repro.core.coo import COO
from repro.core.local_spgemm import (compression_ratio, spgemm_dense,
                                     spgemm_esc, spgemm_flops)


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=True):
    rows = []
    rng = np.random.default_rng(0)
    n = 512
    densities = [0.002, 0.01, 0.05] if quick else \
        [0.001, 0.005, 0.02, 0.05, 0.1, 0.2]
    for d in densities:
        dense = np.where(rng.random((n, n)) < d,
                         rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
        nnz = int((dense != 0).sum())
        A = COO.from_dense(jnp.asarray(dense), cap=nnz + 8)
        flops = int(spgemm_flops(A, A))
        prod_cap = int(flops * 1.2) + 64
        out_cap = min(n * n, prod_cap)
        esc = jax.jit(lambda a, b: spgemm_esc(
            a, b, ARITHMETIC, prod_cap=prod_cap, out_cap=out_cap))
        dns = jax.jit(lambda a, b: spgemm_dense(
            a, b, ARITHMETIC, out_cap=out_cap))
        t_esc = _time(esc, A, A)
        t_dns = _time(dns, A, A)
        cr = float(compression_ratio(A, A))
        rows.append((f"spgemm_esc_d{d}", t_esc, f"flops={flops}"))
        rows.append((f"spgemm_dense_d{d}", t_dns, f"cr={cr:.2f}"))
        rows.append((f"spgemm_winner_d{d}", min(t_esc, t_dns),
                     "esc" if t_esc < t_dns else "dense"))
    return rows
