"""§4.1 reproduction: hash-vs-heap analogue — dense-accumulator vs ESC
local SpGEMM across compression ratios (paper: heap wins at LOW compression
ratio, hash at HIGH; our TPU mapping: ESC-sort ↔ heap, dense tile ↔ hash).

Capacities and the algo pick come from the planner's exact symbolic phase
(core/plan.py, plan_local_spgemm) instead of ad-hoc constants, and the
sweep additionally times the order-tag fast path (row-sorted tiles skip the
expansion sort) against the untagged fallback.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARITHMETIC
from repro.core.coo import COO
from repro.core.local_spgemm import spgemm_dense, spgemm_esc
from repro.core.plan import plan_local_spgemm


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=True):
    rows = []
    rng = np.random.default_rng(0)
    n = 512
    densities = [0.002, 0.01, 0.05] if quick else \
        [0.001, 0.005, 0.02, 0.05, 0.1, 0.2]
    for d in densities:
        dense = np.where(rng.random((n, n)) < d,
                         rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
        nnz = int((dense != 0).sum())
        A = COO.from_dense(jnp.asarray(dense), cap=nnz + 8)   # order='row'
        A_untagged = COO(A.row, A.col, A.val, A.nnz, A.shape, "none")
        plan = plan_local_spgemm(A, A)
        esc = jax.jit(lambda a, b: spgemm_esc(
            a, b, ARITHMETIC, prod_cap=plan.prod_cap, out_cap=plan.out_cap))
        dns = jax.jit(lambda a, b: spgemm_dense(
            a, b, ARITHMETIC, out_cap=plan.out_cap))
        t_esc = _time(esc, A, A)                   # sorted fast path
        t_esc_untagged = _time(esc, A_untagged, A_untagged)  # seed path
        t_dns = _time(dns, A, A)
        rows.append((f"spgemm_esc_d{d}", t_esc, f"flops={plan.flops}"))
        rows.append((f"spgemm_esc_untagged_d{d}", t_esc_untagged,
                     "sort-fallback path"))
        rows.append((f"spgemm_sorted_speedup_d{d}",
                     t_esc_untagged / max(t_esc, 1e-9),
                     "untagged/tagged ratio (>=1 => fast path not slower)"))
        rows.append((f"spgemm_dense_d{d}", t_dns, f"cr={plan.ratio:.2f}"))
        rows.append((f"spgemm_planner_algo_d{d}",
                     t_dns if plan.algo == "dense" else t_esc, plan.algo))
        rows.append((f"spgemm_winner_d{d}", min(t_esc, t_dns),
                     "esc" if t_esc < t_dns else "dense"))
    return rows
