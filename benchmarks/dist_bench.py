"""Distributed SpGEMM benchmarks (Figs 5/6/7 + §4.8) — run as a SUBPROCESS
with forced host devices (the parent benchmark keeps 1 device).

    python benchmarks/dist_bench.py evolution   # Fig 5/6: 2D vs 3D vs merge
    python benchmarks/dist_bench.py scaling     # Fig 7: collective bytes vs p
    python benchmarks/dist_bench.py sweep       # §4.8: overlap x schedule x
                                                # compression + weak/strong

``evolution`` needs a 4x4 grid; on fewer than 16 forced devices it emits
nothing (exit 0) so the REPRO_DEVICES=8 CI mesh can still run the sweep.
"""
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "16"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"

import time                                                    # noqa: E402
import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs                                          # noqa: E402
from repro.core import (ARITHMETIC, DistSpMat, DistSpMat3D, make_grid,      # noqa: E402
                        spgemm_2d, spgemm_3d)
from repro.io import rmat_coo                                  # noqa: E402
from repro.launch.roofline import collective_bytes             # noqa: E402


def _time(fn, *args, reps=5):
    # best-of-reps: forced host devices share one core, so scheduler noise
    # swings single measurements by tens of percent — min is the robust
    # estimator of the true cost
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def evolution(scale=11):
    """Fig 5/6 analogue: SpGEMM variants on the same matrix, same devices.

    The merge axis sweeps the §4.4 strategies: 'sort' is the seed
    concat-and-sort baseline, 'deferred' the merge-engine tree,
    'incremental' the rank-placement accumulator.
    """
    shape, r, c, v = rmat_coo(scale, 8, seed=2)
    mesh = make_grid(4, 4)
    A = DistSpMat.from_global_coo(shape, r, c, v, (4, 4), mesh=mesh,
                                  random_permute=True)
    pc, oc = 1 << 17, 1 << 16
    rows = []
    times = {}
    for variant, merge in [("allgather", "sort"),
                           ("allgather", "deferred"),
                           ("rotation", "sort"),
                           ("rotation", "deferred"),
                           ("rotation", "incremental")]:
        fn = jax.jit(lambda a, b, vr=variant, mg=merge: spgemm_2d(
            a, b, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc,
            variant=vr, merge=mg))
        t = _time(fn, A, A)
        times[(variant, merge)] = t
        coll = collective_bytes(fn.lower(A, A).compile().as_text())
        rows.append((f"spgemm2d_{variant}_{merge}", t,
                     f"collbytes={coll['total']:.0f}"))
    for variant in ("allgather", "rotation"):
        rows.append((f"spgemm2d_{variant}_merge_engine_speedup",
                     times[(variant, "sort")] /
                     max(times[(variant, "deferred")], 1e-9),
                     "sort/deferred (merge engine win)"))
    # 3D CA on (4, 2, 2)
    mesh3 = make_grid(2, 2, layers=4)
    A3 = DistSpMat3D.from_global_coo(shape, r, c, v, (4, 2, 2), "acol",
                                     mesh=mesh3, random_permute=True)
    B3 = DistSpMat3D.from_global_coo(shape, r, c, v, (4, 2, 2), "brow",
                                     mesh=mesh3, random_permute=True)
    fn3 = jax.jit(lambda a, b: spgemm_3d(a, b, ARITHMETIC, mesh=mesh3,
                                         prod_cap=pc, out_cap=oc))
    t3 = _time(fn3, A3, B3)
    coll3 = collective_bytes(fn3.lower(A3, B3).compile().as_text())
    rows.append(("spgemm3d_ca_L4", t3, f"collbytes={coll3['total']:.0f}"))
    return rows


def scaling():
    """Fig 7 analogue (AOT): per-device collective bytes, 2D vs 3D, p↑."""
    rows = []
    shape, r, c, v = rmat_coo(10, 8, seed=3)
    for q, L in [(2, 1), (4, 1), (2, 4)]:
        p = q * q * L
        if p > N_DEV:
            continue
        pc, oc = 1 << 16, 1 << 15
        if L == 1:
            mesh = make_grid(q, q)
            A = DistSpMat.from_global_coo(shape, r, c, v, (q, q), mesh=mesh,
                                          random_permute=True)
            fn = jax.jit(lambda a, b: spgemm_2d(
                a, b, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc))
            coll = collective_bytes(fn.lower(A, A).compile().as_text())
            rows.append((f"ca_scaling_2d_p{p}", 0.0,
                         f"collbytes={coll['total']:.0f}"))
        else:
            mesh = make_grid(q, q, layers=L)
            A3 = DistSpMat3D.from_global_coo(shape, r, c, v, (L, q, q),
                                             "acol", mesh=mesh,
                                             random_permute=True)
            B3 = DistSpMat3D.from_global_coo(shape, r, c, v, (L, q, q),
                                             "brow", mesh=mesh,
                                             random_permute=True)
            fn = jax.jit(lambda a, b: spgemm_3d(
                a, b, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc))
            coll = collective_bytes(fn.lower(A3, B3).compile().as_text())
            rows.append((f"ca_scaling_3d_L{L}_p{p}", 0.0,
                         f"collbytes={coll['total']:.0f}"))
    return rows


def _assert_ok(ok, what):
    if not bool(jnp.all(ok)):
        raise RuntimeError(f"benchmark overflow in {what} — caps too small, "
                           "timings would be garbage")


def sweep():
    """§4.8 trajectory: overlap{on,off} x schedule{rotate,alltoall,bcast,
    hybrid} x compress{off,int8} on the CI q=2 mesh, plus weak/strong
    scaling rows. The ``dist_overlap_speedup_*`` ratios are the gated
    BENCH_dist.json keys."""
    q = 2
    shape, r, c, v = rmat_coo(10, 8, seed=4)
    mesh = make_grid(q, q)
    A = DistSpMat.from_global_coo(shape, r, c, v, (q, q), mesh=mesh,
                                  random_permute=True)
    pc, oc = 1 << 17, 1 << 16
    scheds = {"rotate": "rotate", "alltoall": "alltoall", "bcast": "bcast",
              "hybrid": ("gather",) * (q - 1) + ("bcast",)}
    rows = []
    times = {}
    for sname, sched in scheds.items():
        for overlap in (True, False):
            fn = jax.jit(lambda a, b, s=sched, o=overlap: spgemm_2d(
                a, b, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc,
                merge="deferred", schedule=s, overlap=o))
            _assert_ok(fn(A, A)[1], f"{sname} overlap={overlap}")
            t = _time(fn, A, A)
            times[(sname, overlap)] = t
            coll = collective_bytes(fn.lower(A, A).compile().as_text())
            tag = "overlap" if overlap else "serial"
            rows.append((f"dist2d_{sname}_{tag}", t,
                         f"collbytes={coll['total']:.0f}"))
    for sname in scheds:
        rows.append((f"dist_overlap_speedup_{sname}",
                     times[(sname, False)] / max(times[(sname, True)], 1e-9),
                     "serial/overlap (double-buffer win)"))
    # int8-compressed rotation exchange (overlap on/off), vs the float wire
    cbytes = {}
    for compress in (None, "int8"):
        for overlap in ((True, False) if compress else (True,)):
            fn = jax.jit(lambda a, b, o=overlap, cp=compress: spgemm_2d(
                a, b, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc,
                merge="deferred", schedule="rotate", overlap=o, compress=cp))
            _assert_ok(fn(A, A)[1], f"compress={compress}")
            coll = collective_bytes(fn.lower(A, A).compile().as_text())
            cbytes[compress] = coll["total"]
            if compress:
                tag = "overlap" if overlap else "serial"
                rows.append((f"dist2d_rotate_{tag}_int8", _time(fn, A, A),
                             f"collbytes={coll['total']:.0f}"))
    rows.append(("dist_compress_bytes_ratio",
                 cbytes[None] / max(cbytes["int8"], 1e-9),
                 "float-wire/int8-wire collective bytes (rotate)"))
    rows.extend(_trace_rows(A, mesh, scheds, pc, oc))
    # strong scaling: fixed problem, p up; weak scaling: problem grows with p
    strong_qs = [1, 2] + ([4] if N_DEV >= 16 else [])
    for bq in strong_qs:
        t, cb = _grid_point(bq, scale=10)
        rows.append((f"dist_strong_s10_p{bq * bq}", t, f"collbytes={cb:.0f}"))
    for bq, scale in [(1, 9), (2, 11)] + ([(4, 13)] if N_DEV >= 16 else []):
        t, cb = _grid_point(bq, scale=scale)
        rows.append((f"dist_weak_s{scale}_p{bq * bq}", t,
                     f"collbytes={cb:.0f}"))
    return rows


def _trace_rows(A, mesh, scheds, pc, oc):
    """Flight-recorder pass (§4.8 observability): re-run one EAGER call per
    schedule so the trace carries real per-stage spans — the jitted sweep
    calls above trace once (obs no-ops inside tracing) and replay opaquely.
    Produces the obs-derived BENCH rows and leaves the recorder populated
    for the ``# trace_summary=`` line / ``REPRO_TRACE`` export."""
    obs.enable()
    ctr0 = dict(obs.counters())
    for sname, sched in scheds.items():
        with obs.span("bench.spgemm", schedule=sname):
            out = spgemm_2d(A, A, ARITHMETIC, mesh=mesh, prod_cap=pc,
                            out_cap=oc, merge="deferred", schedule=sched,
                            overlap=True)
            obs.sync(out)
    with obs.span("bench.spgemm", schedule="rotate", compress="int8"):
        out = spgemm_2d(A, A, ARITHMETIC, mesh=mesh, prod_cap=pc,
                        out_cap=oc, merge="deferred", schedule="rotate",
                        overlap=True, compress="int8")
        obs.sync(out)
    ctr = obs.counters()
    delta = lambda k: ctr.get(k, 0) - ctr0.get(k, 0)
    rows = [("dist_trace_span_coverage", obs.coverage("spgemm2d") * 100.0,
             "pct of spgemm2d wall covered by child spans")]
    bin_, bout = delta("dist.compress.bytes_in"), \
        delta("dist.compress.bytes_out")
    if bout:
        rows.append(("dist_compress_value_bytes_ratio", bin_ / bout,
                     f"value payload f32/int8 bytes in={bin_} out={bout}"))
    rows.append(("dist_audit_failures", float(delta("audit.failures")),
                 "obs counter (sweep)"))
    rows.append(("dist_deadline_trips", float(delta("deadline.trips")),
                 "obs counter (sweep)"))
    rows.append(("dist_ladder_rungs", float(delta("ladder.rungs")),
                 "obs counter (sweep)"))
    return rows


def _grid_point(q, *, scale, pc=1 << 20, oc=1 << 18):
    # generous caps: a q=1 grid concentrates the whole expansion on one
    # device; these points exist for the scaling trajectory, not peak rate
    shape, r, c, v = rmat_coo(scale, 8, seed=5)
    mesh = make_grid(q, q)
    A = DistSpMat.from_global_coo(shape, r, c, v, (q, q), mesh=mesh,
                                  random_permute=True)
    fn = jax.jit(lambda a, b: spgemm_2d(a, b, ARITHMETIC, mesh=mesh,
                                        prod_cap=pc, out_cap=oc,
                                        merge="deferred"))
    _assert_ok(fn(A, A)[1], f"grid q={q} scale={scale}")
    t = _time(fn, A, A)
    coll = collective_bytes(fn.lower(A, A).compile().as_text())
    return t, coll["total"]


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "evolution"
    if which == "evolution" and N_DEV < 16:
        print(f"# evolution needs 16 devices, have {N_DEV} — skipped",
              file=sys.stderr)
        sys.exit(0)
    fns = {"evolution": evolution, "scaling": scaling, "sweep": sweep}
    rows = fns[which]()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if obs.enabled():
        import json
        print("# trace_summary=" + json.dumps(obs.snapshot()))
