"""Fig 3 reproduction: best SpMSpV/SpMV variant vs matrix/vector sparsity.

R-MAT matrix (Graph500 params), sweep average nnz/column × vector density,
time each local variant, report the winner per cell (the paper's rule of
thumb: sort ≲0.5% < bucket ≲10% < SPA; SpMSpV competitive with SpMV even
at 50% density).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARITHMETIC
from repro.core.coo import COO
from repro.core.plan import plan_local_spmspv
from repro.core.spmv_local import (SPMSPV_VARIANTS, spmv_row,
                                   spvec_from_dense)
from repro.io import rmat_coo


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))          # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6     # us


def run(scale=12, quick=True):
    rows = []
    n = 1 << scale
    edge_factors = [4, 16] if quick else [2, 4, 8, 16, 32]
    densities = [0.001, 0.02, 0.3] if quick else \
        [0.0005, 0.002, 0.01, 0.05, 0.2, 0.5]
    for ef in edge_factors:
        shape, r, c, v = rmat_coo(scale, ef, seed=1)
        cap = len(r) + 8
        A = COO.from_entries(shape, r, c, v, cap=cap).sort("col")
        rng = np.random.default_rng(0)
        for dens in densities:
            f = max(1, int(dens * n))
            xd = np.zeros(n, np.float32)
            xd[rng.choice(n, f, replace=False)] = 1.0
            xi, xv, xn = spvec_from_dense(jnp.asarray(xd), cap=f + 8)
            plan = plan_local_spmspv(A, f)     # caps + Fig-3 variant pick
            prod_cap, out_cap = plan.prod_cap, plan.out_cap
            best, best_t = None, np.inf
            for name, fn in SPMSPV_VARIANTS.items():
                jfn = jax.jit(lambda a, i, vv, nn, fn=fn: fn(
                    a, i, vv, nn, ARITHMETIC, prod_cap=prod_cap,
                    out_cap=out_cap))
                t = _time(jfn, A, xi, xv, xn)
                rows.append((f"spmspv_{name}_ef{ef}_d{dens}", t, ""))
                if t < best_t:
                    best, best_t = name, t
            jmv = jax.jit(lambda a, x: spmv_row(a, x, ARITHMETIC))
            t = _time(jmv, A, jnp.asarray(xd))
            rows.append((f"spmv_row_ef{ef}_d{dens}", t, ""))
            winner = best if best_t < t else "spmv"
            rows.append((f"fig3_best_ef{ef}_d{dens}", min(best_t, t),
                         winner))
            rows.append((f"fig3_planner_pick_ef{ef}_d{dens}", 0.0,
                         "spmv" if plan.use_spmv else plan.variant))
    return rows
