"""Aggregate dry-run JSONs into the §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_all(pattern="dryrun_*.json"):
    out = {}
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            d = json.load(f)
        m = d["meta"]
        key = (m["arch"], m["shape"], "mp" if m["multi_pod"] else "sp",
               os.path.basename(path))
        out[key] = d
    return out


def fmt_s(x):
    return f"{x:.3e}" if x < 1e-2 else f"{x:.3f}"


def table(markdown=True, mesh="sp", only_baseline=True):
    rows = []
    for (arch, shape, m, fname), d in load_all().items():
        if m != mesh:
            continue
        # baseline files are exactly dryrun_<arch>_<shape>_<sp|mp>.json;
        # anything longer is a §Perf variant (plan override or tag)
        is_baseline = fname == f"dryrun_{arch}_{shape}_{m}.json"
        if only_baseline and not is_baseline:
            continue
        t = d["terms_seconds"]
        mem = d.get("memory_per_device", {})
        fits = mem.get("total_transient", 0) + mem.get("args", 0)
        rows.append([
            arch, shape,
            fmt_s(t["compute"]), fmt_s(t["memory"]), fmt_s(t["collective"]),
            d["dominant"],
            f"{d['model_flops_global']:.2e}",
            f"{d['useful_flops_ratio']:.3f}" if d["useful_flops_ratio"]
            else "-",
            f"{d['roofline_fraction'] * 100:.2f}%" if d["roofline_fraction"]
            else "-",
            f"{fits / 2**30:.1f}",
        ])
    rows.sort()
    header = ["arch", "shape", "T_comp(s)", "T_mem(s)", "T_coll(s)",
              "bound", "MODEL_FLOPS", "useful", "roofline%", "GiB/dev"]
    if not markdown:
        return [header] + rows
    out = ["| " + " | ".join(header) + " |",
           "|" + "---|" * len(header)]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def run(quick=True):
    """Benchmark-driver entry: one row per dry-run cell found."""
    del quick
    rows = []
    for (arch, shape, m, fname), d in load_all().items():
        variant = fname[len(f"dryrun_{arch}_{shape}_{m}"):-len(".json")]
        tag = f"roofline_{arch}_{shape}_{m}" + \
            (f"[{variant.strip('_')}]" if variant else "")
        rows.append((tag, d["step_time_lower_bound_s"] * 1e6,
                     f"dom={d['dominant']};frac={d['roofline_fraction']}"))
    return rows


if __name__ == "__main__":
    print(table())
