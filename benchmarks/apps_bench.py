"""Application benchmarks: FastSV (Fig 8), HipMCL breakdown (Fig 9),
PageRank (Fig 10), BFS — single-device grid; the distributed variants run
under tests/dist_scenarios.py and dist_bench.py.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DistSpMat, make_grid
from repro.io import rmat_coo


def run(quick=True):
    rows = []
    mesh = make_grid(1, 1)
    scale = 9 if quick else 12
    shape, r, c, v = rmat_coo(scale, 8, seed=4, symmetrize=True,
                              drop_self_loops=True)
    A = DistSpMat.from_global_coo(shape, r, c, v, (1, 1), mesh=mesh)

    from repro.apps import bfs_levels, fastsv, pagerank, triangle_count

    t0 = time.perf_counter()
    labels = fastsv(A, mesh=mesh)
    t_sv = (time.perf_counter() - t0) * 1e6
    rows.append((f"fastsv_rmat{scale}", t_sv, f"ncc={len(set(labels))}"))

    t0 = time.perf_counter()
    pr = pagerank(A, mesh=mesh, max_iters=20, tol=0)
    t_pr = (time.perf_counter() - t0) * 1e6
    rows.append((f"pagerank20_rmat{scale}", t_pr,
                 f"top={float(pr.max()):.5f}"))

    src = int(r[0])        # a vertex with edges (R-MAT isolates many)
    t0 = time.perf_counter()
    lv = bfs_levels(A, src, mesh=mesh)
    t_bfs = (time.perf_counter() - t0) * 1e6
    rows.append((f"bfs_rmat{scale}", t_bfs,
                 f"reached={(lv >= 0).sum()}"))

    t0 = time.perf_counter()
    ntri = triangle_count(A, mesh=mesh, prod_cap=1 << 18, out_cap=1 << 17)
    t_tri = (time.perf_counter() - t0) * 1e6
    rows.append((f"tricount_rmat{scale}", t_tri, f"tri={ntri}"))

    # HipMCL runtime breakdown (Fig 9b): SpGEMM share of total
    from repro.core import ARITHMETIC, spgemm_2d
    from repro.apps.hipmcl import _normalize_cols, hipmcl
    # planted two-cluster graph (R-MAT hubs blow up MCL expansion flops)
    n = 48
    rng = np.random.default_rng(5)
    dense = (rng.random((n, n)) < 0.08).astype(np.float32)
    dense[:n // 2, n // 2:] *= (rng.random((n // 2, n // 2)) < 0.1)
    dense[n // 2:, :n // 2] = dense[:n // 2, n // 2:].T
    dense = np.maximum(dense, dense.T)
    np.fill_diagonal(dense, 1.0)
    r2, c2 = np.nonzero(dense)
    A2 = DistSpMat.from_global_coo((n, n), r2.astype(np.int64),
                                   c2.astype(np.int64), dense[r2, c2],
                                   (1, 1), mesh=mesh)
    pc, oc = 1 << 17, 1 << 12
    c0 = _normalize_cols(A2, mesh=mesh)
    t0 = time.perf_counter()
    spgemm_2d(c0, c0, ARITHMETIC, mesh=mesh, prod_cap=pc, out_cap=oc)
    t_exp = time.perf_counter() - t0
    t0 = time.perf_counter()
    nit = 3
    hipmcl(A2, mesh=mesh, max_iters=nit, prod_cap=pc, out_cap=oc)
    t_total = time.perf_counter() - t0
    rows.append((f"hipmcl_planted{n}", t_total * 1e6,
                 f"spgemm_share~{min(nit * t_exp / t_total, 1.0):.2f}"))
    return rows
