"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Distributed benchmarks run in
subprocesses with forced host devices; everything else runs on the single
real device. ``--full`` widens the sweeps.

``--json`` additionally writes the perf-trajectory artifacts (repo root):
``BENCH_spgemm.json`` from the spgemm_local rows, ``BENCH_dist.json``
from the distributed rows (the §4.8 sweep + evolution + scaling) and
``BENCH_robust.json`` from the elastic-recovery rows (time-to-detect,
regrid, checkpoint, steps-lost), each as benchmark rows plus every
``*_speedup*``/``*_ratio`` key, so future PRs can diff perf trajectories.
Subsets that would silently omit an artifact are rejected: with
``--only``, ``--json`` requires ``spgemm_local``, ``dist`` and ``robust``
in the subset, and a failed dist subprocess is a hard error rather than a
skipped artifact. CI's bench-smoke job runs ``REPRO_DEVICES=8 python -m
benchmarks.run --only spgemm_local,dist,robust --json`` from the repo
root — the ``-m`` form is required so the ``benchmarks`` package
resolves.

  robust          §8      elastic recovery: detect/regrid/ckpt/steps-lost
  spmspv_sweep    Fig 3   SpMSpV/SpMV variant selection vs sparsity
  spgemm_local    §4.1    hash↔dense vs heap↔ESC crossover
  dist(evolution) Fig 5/6 2D SUMMA variants vs 3D CA (time + coll bytes)
  dist(scaling)   Fig 7   CA collective bytes vs p (AOT)
  apps            Fig 8/9/10  FastSV / HipMCL breakdown / PageRank / BFS
  io              Table 5 ASCII vs binary vs label-format parallel I/O
  kernels         §5      kernel-path microbenches (oracle timings)
  roofline        §Roofline  aggregated dry-run cells (if present)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def write_bench_json(rows, path=None, trace_summary=None):
    """Trajectory artifact: µs per benchmark + every speedup/ratio key.

    ``trace_summary`` (obs.snapshot() or a dict of them) is embedded
    verbatim so each BENCH_*.json carries its flight-recorder view —
    per-site span stats, counter totals, deadline windows."""
    path = path or os.path.join(ROOT, "BENCH_spgemm.json")
    doc = {
        "benchmarks": {name: {"us": round(us, 1), "derived": derived}
                       for name, us, derived in rows},
        "speedups": {name: round(us, 3) for name, us, _ in rows
                     if "speedup" in name or "ratio" in name},
    }
    if trace_summary is not None:
        doc["trace_summary"] = trace_summary
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.relpath(path)}", file=sys.stderr)
    return doc


def run_dist(which: str, devices: int | None = None):
    """Run one dist_bench mode in a forced-device subprocess.

    Returns ``(rows, trace_summary)`` — the parsed ``(name, us, derived)``
    rows plus the child's flight-recorder snapshot (from its
    ``# trace_summary=`` stdout line; None when the child recorded
    nothing) — or ``(None, None)`` on failure (the caller decides whether
    that is fatal — it is under ``--json``). The child records with
    ``REPRO_OBS=1``; a parent ``REPRO_TRACE=<p>`` is rewritten to
    ``<p-base>.dist_<which>.json`` so each subprocess writes its own
    Chrome trace instead of clobbering the parent's.
    """
    if devices is None:
        devices = int(os.environ.get("REPRO_DEVICES", "16"))
    env = dict(os.environ, REPRO_DEVICES=str(devices), REPRO_OBS="1")
    env.pop("XLA_FLAGS", None)
    trace = os.environ.get("REPRO_TRACE")
    if trace:
        base, ext = os.path.splitext(trace)
        env["REPRO_TRACE"] = f"{base}.dist_{which}{ext or '.json'}"
    script = os.path.join(os.path.dirname(__file__), "dist_bench.py")
    proc = subprocess.run([sys.executable, script, which],
                          capture_output=True, text=True, env=env,
                          timeout=3600)
    if proc.returncode != 0:
        print(f"dist_bench_{which},0.0,FAILED", flush=True)
        sys.stderr.write(proc.stderr[-2000:])
        return None, None
    rows, summary = [], None
    for line in proc.stdout.strip().splitlines():
        if line.startswith("# trace_summary="):
            try:
                summary = json.loads(line.split("=", 1)[1])
            except ValueError:
                summary = None
            continue
        print(line)
        if not line or line.startswith("#"):
            continue
        name, us, derived = line.split(",", 2)
        rows.append((name, float(us), derived))
    return rows, summary


def kernels_bench(quick=True):
    import time
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    rows = []
    rng = np.random.default_rng(0)

    def t(fn, *args, reps=3):
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn(*args))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jfn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    for kind in ("plus_times", "min_plus", "max_min"):
        rows.append((f"kernel_semiring_{kind}_ref256",
                     t(lambda x, y, k=kind: ref.semiring_matmul(x, y, k),
                       a, a), "oracle-on-CPU"))
    B, S, H, d = 1, 512, 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
    rows.append(("kernel_flash_attn_ref512",
                 t(lambda x: ref.flash_attention(x, x, x, True), q),
                 "oracle-on-CPU"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_spgemm.json (spgemm rows + speedups)")
    args, _ = ap.parse_known_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None
    if args.json and only is not None and not {"spgemm_local", "dist",
                                               "robust"} <= only:
        # each artifact is built from its section's rows; silently writing
        # nothing (the old behavior) made perf-trajectory runs vacuous
        ap.error("--json writes BENCH_spgemm.json from the spgemm_local "
                 "rows, BENCH_dist.json from the dist rows and "
                 "BENCH_robust.json from the robust rows; include all "
                 "three in --only (or drop --only)")

    def want(name):
        return only is None or name in only

    obs = None
    if args.json:
        # flight recorder on for the in-process sections: each BENCH json
        # embeds its own section's snapshot (reset between sections)
        from repro import obs
        obs.enable()

    if want("spmspv"):
        from benchmarks import spmspv_sweep
        emit(spmspv_sweep.run(quick=quick))
    if want("spgemm_local"):
        from benchmarks import spgemm_local
        if obs:
            obs.reset()
        rows = spgemm_local.run(quick=quick)
        emit(rows)
        if args.json:
            write_bench_json(rows, trace_summary=obs.snapshot())
    if want("dist"):
        parts = [run_dist("sweep"), run_dist("evolution"),
                 run_dist("scaling")]
        if args.json:
            if any(rows is None for rows, _ in parts):
                raise SystemExit(
                    "dist benchmark subprocess failed — refusing to write "
                    "a partial BENCH_dist.json")
            summaries = {mode: s for mode, (_, s) in
                         zip(("sweep", "evolution", "scaling"), parts)
                         if s is not None}
            write_bench_json([r for rows, _ in parts for r in rows],
                             path=os.path.join(ROOT, "BENCH_dist.json"),
                             trace_summary=summaries or None)
    if want("robust"):
        from benchmarks import robust_bench
        if obs:
            obs.reset()
        rows = robust_bench.run(quick=quick)
        emit(rows)
        if args.json:
            write_bench_json(rows,
                             path=os.path.join(ROOT, "BENCH_robust.json"),
                             trace_summary=obs.snapshot())
    if want("apps"):
        from benchmarks import apps_bench
        emit(apps_bench.run(quick=quick))
    if want("io"):
        from benchmarks import io_bench
        emit(io_bench.run(quick=quick))
    if want("kernels"):
        emit(kernels_bench(quick=quick))
    if want("roofline"):
        from benchmarks import roofline_table
        emit(roofline_table.run(quick=quick))


if __name__ == "__main__":
    main()
