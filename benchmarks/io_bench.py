"""Table 5 reproduction: parallel I/O times — ASCII (MatrixMarket) vs
binary, 1..8 readers/writers; plus the label-format two-pass reader.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.io import (read_binary, read_generalized_tuples, read_mm_parallel,
                      rmat_coo, write_binary, write_mm_parallel)


def run(quick=True):
    rows = []
    scale = 13 if quick else 16
    shape, r, c, v = rmat_coo(scale, 8, seed=6)
    with tempfile.TemporaryDirectory() as td:
        mtx = os.path.join(td, "g.mtx")
        binp = os.path.join(td, "g.cbb")
        lbl = os.path.join(td, "g.lbl")
        t0 = time.perf_counter()
        write_mm_parallel(mtx, shape, r, c, v, nwriters=4)
        rows.append(("io_write_ascii_w4", (time.perf_counter() - t0) * 1e6,
                     f"nnz={len(r)}"))
        t0 = time.perf_counter()
        write_binary(binp, shape, r, c, v.astype(np.float64), nwriters=4)
        rows.append(("io_write_binary_w4", (time.perf_counter() - t0) * 1e6,
                     f"bytes={os.path.getsize(binp)}"))
        for nr in (1, 2, 4, 8):
            t0 = time.perf_counter()
            read_mm_parallel(mtx, nreaders=nr)
            rows.append((f"io_read_ascii_r{nr}",
                         (time.perf_counter() - t0) * 1e6, ""))
        for nr in (1, 4):
            t0 = time.perf_counter()
            read_binary(binp, nreaders=nr)
            rows.append((f"io_read_binary_r{nr}",
                         (time.perf_counter() - t0) * 1e6, ""))
        # label format (ReadGeneralizedTuples) on string labels
        ns = min(len(r), 100_000)
        with open(lbl, "w") as f:
            for i in range(ns):
                f.write(f"prot{r[i]}\tprot{c[i]}\t{v[i]:.3f}\n")
        t0 = time.perf_counter()
        shape2, *_ = read_generalized_tuples(lbl, nworkers=4)
        rows.append(("io_read_label_w4", (time.perf_counter() - t0) * 1e6,
                     f"nvert={shape2[0]}"))
    return rows
