"""Serving example: batched prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-3-2b
(uses the smoke config of the chosen arch; --tokens controls generation)
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke
from repro.models import Model, init_params
from repro.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    model = Model(cfg)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.tokens + 1
    t0 = time.time()
    out = greedy_generate(model, params, prompt, max_len, args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} "
          f"generated {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl compile)")
    print("sample token ids:", np.asarray(out[0])[:16])


if __name__ == "__main__":
    main()
