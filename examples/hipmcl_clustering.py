"""HipMCL end-to-end: protein-clustering pipeline (paper §7.5).

Generates a synthetic protein-similarity network with planted clusters,
writes it in the MCL LABEL format (string protein ids), reads it back with
the two-pass ReadGeneralizedTuples reader (which relabels + load-balances),
clusters with Markov clustering, and reports cluster quality.

    PYTHONPATH=src python examples/hipmcl_clustering.py
"""
import os
import tempfile

import numpy as np

from repro.apps import hipmcl
from repro.core import DistSpMat, make_grid
from repro.io import read_generalized_tuples


def planted_network(k=6, size=12, p_in=0.7, p_out=0.01, seed=0):
    rng = np.random.default_rng(seed)
    n = k * size
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            same = i // size == j // size
            if rng.random() < (p_in if same else p_out):
                w = rng.random() * 0.5 + (0.5 if same else 0.05)
                edges.append((i, j, w))
    return n, edges


def main():
    n, edges = planted_network()
    truth = np.arange(n) // 12
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "proteins.lbl")
        with open(path, "w") as f:
            for i, j, w in edges:
                f.write(f"PROT_{i:04d}\tPROT_{j:04d}\t{w:.4f}\n")
                f.write(f"PROT_{j:04d}\tPROT_{i:04d}\t{w:.4f}\n")
        shape, rows, cols, vals, labels = read_generalized_tuples(path, 4)
        print(f"read {shape[0]} proteins, {len(rows)} similarities "
              f"(labels relabeled + load-balanced)")
        # self-loops (MCL standard)
        loops = np.arange(shape[0], dtype=np.int64)
        rows = np.concatenate([rows, loops])
        cols = np.concatenate([cols, loops])
        vals = np.concatenate([vals, np.full(shape[0], 1.0)])
        mesh = make_grid(1, 1)
        A = DistSpMat.from_global_coo(shape, rows, cols, vals, (1, 1),
                                      mesh=mesh)
        clusters = hipmcl(A, mesh=mesh, inflation=2.0, max_iters=10,
                          prod_cap=1 << 17, out_cap=1 << 15)
    # map back through the label permutation and score vs planted truth
    orig = np.array([int(lb.split("_")[1]) for lb in labels])
    pred = np.empty(n, np.int64)
    pred[orig] = clusters
    # purity
    correct = 0
    for c in set(pred):
        members = truth[pred == c]
        correct += np.bincount(members).max()
    print(f"clusters found: {len(set(pred))} (planted 6), "
          f"purity {correct / n:.3f}")


if __name__ == "__main__":
    main()
