"""End-to-end training driver: data pipeline → train loop → checkpoint →
crash-resume. Defaults to a laptop-sized model; ``--arch`` selects any
assigned architecture's smoke config, ``--prod`` uses the full config
(needs the production mesh).

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --steps 60 --resume  # later

The loop demonstrates the fault-tolerance contract (DESIGN.md §8):
deterministic data by step, atomic checkpoints, auto-resume from the
latest complete step.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke, get_config
from repro.models import Model, init_params
from repro.models.config import ModelConfig
from repro.train import (AdamWConfig, SyntheticLM, init_opt_state,
                         latest_step, make_train_step, restore_checkpoint,
                         save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (smoke config); default: custom "
                         "~20M decoder")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    if args.arch:
        cfg = get_smoke(args.arch).scaled(vocab=2048)
    else:
        cfg = ModelConfig(name="demo-20m", kind="decoder", n_layers=4,
                          d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                          d_ff=1024, vocab=2048).validate()
    model = Model(cfg)
    params = init_params(cfg, seed=0)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          decay_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=7)

    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        (params, opt), start = restore_checkpoint(
            args.ckpt_dir, (params, opt))
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)")
        if (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1, (params, opt))
            print(f"checkpoint -> {path}")
    print("done. resume anytime with --resume.")


if __name__ == "__main__":
    main()
