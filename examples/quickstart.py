"""Quickstart: the sparse library in 60 lines.

Builds an R-MAT graph, runs BFS / PageRank / connected components /
triangle counting, and shows a user-defined semiring (min-plus shortest
paths via SpGEMM powers) — the CombBLAS 2.0 tour.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ARITHMETIC, MIN_PLUS, DistSpMat, make_grid,
                        make_semiring, spgemm_2d)
from repro.apps import bfs_levels, fastsv, pagerank, triangle_count
from repro.io import rmat_coo


def main():
    mesh = make_grid(1, 1)           # same code runs on any (pr, pc) grid
    shape, rows, cols, vals = rmat_coo(9, 8, seed=0, symmetrize=True,
                                       drop_self_loops=True)
    A = DistSpMat.from_global_coo(shape, rows, cols, vals, (1, 1),
                                  mesh=mesh, random_permute=True)
    print(f"graph: {shape[0]} vertices, {len(rows)} edges")

    lv = bfs_levels(A, source=0, mesh=mesh)
    print(f"BFS: reached {(lv >= 0).sum()} vertices, "
          f"eccentricity {lv.max()}")

    pr = pagerank(A, mesh=mesh, max_iters=30)
    print(f"PageRank: top vertex {int(np.argmax(pr))} "
          f"score {pr.max():.5f}")

    cc = fastsv(A, mesh=mesh)
    print(f"Connected components: {len(set(cc))}")

    tri = triangle_count(A, mesh=mesh, prod_cap=1 << 18, out_cap=1 << 17)
    print(f"Triangles: {tri}")

    # --- user-defined semiring: 2-hop shortest paths via min-plus SpGEMM
    W = DistSpMat.from_global_coo(
        shape, rows, cols,
        np.random.default_rng(0).random(len(rows)).astype(np.float32) + 0.1,
        (1, 1), mesh=mesh)
    P2, ok = spgemm_2d(W, W, MIN_PLUS, mesh=mesh, prod_cap=1 << 20,
                       out_cap=1 << 17)
    print(f"min-plus A^2: {int(P2.total_nnz)} 2-hop paths, ok={bool(ok.all())}")

    # --- heterogeneous user algebra: count common neighbors (plus_pair)
    plus_pair = make_semiring(jnp.add, 0, lambda a, b: jnp.ones((), jnp.float32),
                         tag="sum", name="plus_pair")
    CN, ok = spgemm_2d(A, A, plus_pair, mesh=mesh, prod_cap=1 << 20,
                       out_cap=1 << 17)
    print(f"common-neighbor counts: nnz={int(CN.total_nnz)}")


if __name__ == "__main__":
    main()
