"""Counter-determinism scenario: one seeded distributed workload under the
flight recorder, counters printed as JSON on the last stdout line.

Run twice by tests/test_obs.py (subprocess, REPRO_DEVICES forced host
devices) — byte counters, retry counts, and event counts must be
IDENTICAL across runs: they derive only from data sizes and control-flow
decisions, never from timing (recorder design rule 3).
"""
import json
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV}")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np                                             # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs                                          # noqa: E402
from repro.core import ARITHMETIC, DistSpMat, make_grid       # noqa: E402
from repro.core.plan import spgemm as spgemm_planned          # noqa: E402


def main():
    obs.enable()
    mesh = make_grid(2, 2)
    rng = np.random.default_rng(7)
    n, nnz = 128, 900
    r = rng.integers(0, n, nnz).astype(np.int64)
    c = rng.integers(0, n, nnz).astype(np.int64)
    v = rng.random(nnz).astype(np.float32)
    A = DistSpMat.from_global_coo((n, n), r, c, v, (2, 2), mesh=mesh)
    spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
    spgemm_planned(A, A, ARITHMETIC, mesh=mesh, compress="int8")
    snap = obs.snapshot()
    out = dict(snap["counters"])
    out["__events__"] = snap["events"]
    print(json.dumps(out, sort_keys=True))


if __name__ == "__main__":
    main()
