"""Training substrate tests: optimizer, accumulation, compression,
checkpointing (incl. cross-mesh elastic restore), data determinism."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import Model, init_params
from repro.train import (AdamWConfig, SyntheticLM, init_opt_state,
                         make_train_step, restore_checkpoint,
                         save_checkpoint, latest_step)

CFG = ModelConfig(name="tiny", kind="decoder", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab=256).validate()


def make_all(lr=3e-3, accum=1, compressor=None):
    model = Model(CFG)
    params = init_params(CFG, seed=0)
    opt = init_opt_state(params)
    fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=lr, warmup_steps=2, decay_steps=50),
        accum=accum, compressor=compressor))
    return model, params, opt, fn


class TestOptimizer:
    def test_adamw_on_quadratic(self):
        # AdamW minimizes a quadratic (sanity of the update math)
        from repro.train.optimizer import adamw_update
        p = {"w": jnp.array([5.0, -3.0])}
        st = init_opt_state(p)
        cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                          decay_steps=10**6, min_lr_frac=1.0)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, st, _ = adamw_update(p, g, st, cfg)
        assert float(jnp.abs(p["w"]).max()) < 1e-2

    def test_loss_decreases(self):
        model, params, opt, fn = make_all()
        data = SyntheticLM(CFG.vocab, 64, 4, seed=1)
        losses = []
        for step in range(25):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            params, opt, m = fn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses

    def test_grad_accumulation_equivalent(self):
        """Microbatched grads == full-batch grads (before the optimizer:
        Adam sign-amplifies float-reassociation noise on near-zero grads,
        so the equivalence contract is on gradients)."""
        model = Model(CFG)
        params = init_params(CFG, seed=0)
        data = SyntheticLM(CFG.vocab, 32, 8, seed=2)
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))

        def loss(p, b):
            return model.loss(p, b)[0]

        g_full = jax.grad(loss)(params, batch)
        b1 = jax.tree.map(lambda x: x[:4], batch)
        b2 = jax.tree.map(lambda x: x[4:], batch)
        g1 = jax.grad(loss)(params, b1)
        g2 = jax.grad(loss)(params, b2)
        g_acc = jax.tree.map(lambda a, b: (a + b) / 2, g1, g2)
        for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-3, atol=2e-4)


class TestCompression:
    def test_int8_close(self):
        from repro.dist.compression import int8_quantize
        g = {"a": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 64)), jnp.float32)}
        q = int8_quantize(g)
        err = float(jnp.abs(q["a"] - g["a"]).max())
        assert err < float(jnp.abs(g["a"]).max()) / 100
        # training still converges with compression in the loop
        model, params, opt, fn = make_all(compressor=int8_quantize)
        data = SyntheticLM(CFG.vocab, 64, 4, seed=3)
        losses = []
        for step in range(15):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            params, opt, m = fn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_topk_error_feedback_preserves_mass(self):
        from repro.dist.compression import make_topk_error_feedback
        init, compress = make_topk_error_feedback(frac=0.1)
        g = {"a": jnp.asarray(np.random.default_rng(1)
                              .standard_normal(1000), jnp.float32)}
        state = init(g)
        kept, state = compress(g, state)
        nz = float(jnp.sum(kept["a"] != 0))
        assert nz <= 110  # ~10%
        # error feedback: kept + residual == original
        np.testing.assert_allclose(np.asarray(kept["a"] + state["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)


class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        model, params, opt, fn = make_all()
        d = str(tmp_path / "ck")
        for s in (10, 20, 30, 40):
            save_checkpoint(d, s, (params, opt), keep=2)
        assert latest_step(d) == 40
        steps = sorted(int(x[5:]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [30, 40]           # retention
        (p2, o2), s = restore_checkpoint(d, (params, opt))
        assert s == 40
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_partial_checkpoint_visible(self, tmp_path):
        # a .tmp dir must never be picked up
        model, params, opt, fn = make_all()
        d = str(tmp_path / "ck")
        save_checkpoint(d, 5, (params,))
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert latest_step(d) == 5

    def test_elastic_cross_mesh_restore(self, tmp_path):
        """Save on a (2,4) mesh, restore onto (4,2) — subprocess, 8 devs."""
        script = os.path.join(os.path.dirname(__file__),
                              "elastic_scenario.py")
        env = dict(os.environ, REPRO_DEVICES="8")
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, script, str(tmp_path)],
                              capture_output=True, text=True, env=env,
                              timeout=600)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "PASS elastic" in proc.stdout


class TestData:
    def test_deterministic_by_step(self):
        d1 = SyntheticLM(256, 32, 4, seed=5)
        d2 = SyntheticLM(256, 32, 4, seed=5)
        b1, b2 = d1.batch_at(7), d2.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d1.batch_at(8)["tokens"], b1["tokens"])

    def test_prefetcher(self):
        from repro.train.data import Prefetcher
        src = SyntheticLM(256, 16, 2, seed=6)
        pf = Prefetcher(src, start_step=3)
        b = pf.next()
        np.testing.assert_array_equal(b["tokens"],
                                      src.batch_at(3)["tokens"])
        pf.close()

    def test_prefetcher_relays_worker_exception(self):
        """A source that dies must surface its exception in next() — never
        a silently dead worker with next() blocking forever (and batches
        queued before the failure are still delivered in order)."""
        from repro.train.data import Prefetcher

        class Dies:
            def __init__(self):
                self.good = SyntheticLM(256, 16, 2, seed=7)

            def batch_at(self, step):
                if step >= 2:
                    raise OSError("shard server went away")
                return self.good.batch_at(step)

        pf = Prefetcher(Dies(), depth=1)
        got = [pf.next(), pf.next()]          # the two pre-failure batches
        np.testing.assert_array_equal(got[0]["tokens"],
                                      Dies().good.batch_at(0)["tokens"])
        with pytest.raises(OSError, match="shard server went away"):
            pf.next()
        pf.close()

    def test_prefetcher_close_joins_blocked_worker(self):
        """Regression: close() used to drain once then join — the worker
        could re-fill the depth-1 queue between the two and stay blocked in
        put() forever (silent thread leak). close() must actually reap it
        and report success."""
        from repro.train.data import Prefetcher
        pf = Prefetcher(SyntheticLM(256, 16, 2, seed=8), depth=1)
        pf.next()                 # worker is now blocked re-filling
        assert pf.close() is True
        assert not pf.t.is_alive()

    def test_prefetcher_close_warns_on_stuck_source(self):
        """A worker stuck INSIDE source.batch_at can't be reaped — close()
        must say so loudly and return False, not silently leak."""
        import threading
        from repro.train.data import Prefetcher
        release = threading.Event()

        class Hangs:
            def batch_at(self, step):
                release.wait()           # simulated hung shard server
                return {"tokens": np.zeros((2, 16), np.int32)}

        pf = Prefetcher(Hangs(), depth=1)
        try:
            with pytest.warns(RuntimeWarning, match="still alive"):
                assert pf.close(timeout=0.3) is False
        finally:
            release.set()                # let the daemon thread exit
            pf.t.join(timeout=5.0)
