"""Crash-and-shrink elastic recovery scenario (subprocess harness).

Proves the mesh-independent checkpoint + regrid story end to end, for
PageRank and FastSV:

  baseline   REPRO_DEVICES=4 (1x1 grid): uninterrupted run, result saved.
  crash      REPRO_DEVICES=8 (2x2 grid): ``loop.device_loss:crash:at=K``
             raises TopologyError mid-run; CheckpointedLoop saves the last
             completed iteration and the process dies (prints CRASHED).
  resume     REPRO_DEVICES=4 (1x1 grid): same checkpoint dir — restores the
             global state onto the SMALLER grid and finishes. Result must be
             bitwise-equal to baseline (prints "PASS resume:<app>").
  live       REPRO_DEVICES=8, ``elastic=True``: the same injected device
             loss is survived in-process — checkpoint, regrid 2x2 -> 1x1,
             re-run the interrupted iteration, continue. Bitwise vs
             baseline again (prints "PASS live:<app>").
  all        orchestrates the four as subprocesses for both apps.

Grid policy: q = isqrt(ndev // 2) — the largest square grid that leaves 2x
hot-spare headroom (8 devices -> 2x2, 4 -> 1x1), so the 8 -> 4 shrink is a
genuine grid change.

Bitwise-across-grids is engineered per app: FastSV is exact int32 min
arithmetic (grid-invariant by construction); the PageRank instance uses a
graph where every out-degree is exactly 2, alpha=0.5 and n=32, so every
value in the iteration is a dyadic float32 and each row sum has exactly two
addends — no rounding anywhere, on any grid.
"""
import os
import subprocess
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "4"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"

import numpy as np                                            # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

N_PR = 32      # pagerank vertices
N_SV = 64      # fastsv vertices (two 32-vertex path components)
CRASH_AT = 4   # device loss on the 4th loop entry (iteration index 3)


def grid_for(ndev: int) -> int:
    from math import isqrt
    return max(isqrt(ndev // 2), 1)


def build_pagerank(q: int):
    """A[dst, src]: src i -> (i+1)%n and (i+17)%n. Out-degree exactly 2."""
    from repro.core import DistSpMat, make_grid
    n = N_PR
    src = np.repeat(np.arange(n, dtype=np.int64), 2)
    dst = np.empty(2 * n, np.int64)
    dst[0::2] = (np.arange(n) + 1) % n
    dst[1::2] = (np.arange(n) + 17) % n
    mesh = make_grid(q, q)
    a = DistSpMat.from_global_coo((n, n), dst, src,
                                  np.ones(2 * n, np.float32), (q, q),
                                  mesh=mesh, cap=1024)
    return a, mesh


def build_fastsv(q: int):
    """Symmetric: path 0..31 plus path 32..63 (two components)."""
    from repro.core import DistSpMat, make_grid
    n = N_SV
    r = []
    for lo in (0, 32):
        for i in range(lo, lo + 31):
            r.append((i, i + 1))
            r.append((i + 1, i))
    rows = np.array([e[0] for e in r], np.int64)
    cols = np.array([e[1] for e in r], np.int64)
    mesh = make_grid(q, q)
    a = DistSpMat.from_global_coo((n, n), rows, cols,
                                  np.ones(len(r), np.float32), (q, q),
                                  mesh=mesh, cap=1024)
    return a, mesh


def run_app(app: str, q: int, ckpt: str | None, elastic: bool) -> np.ndarray:
    if app == "pagerank":
        from repro.apps import pagerank
        a, mesh = build_pagerank(q)
        # tol=0.0 -> fixed 6 iterations; alpha=0.5 keeps every constant
        # dyadic (teleport = 1/64, r0 = 1/32)
        return pagerank(a, mesh=mesh, alpha=0.5, tol=0.0, max_iters=6,
                        checkpoint_dir=ckpt, elastic=elastic)
    from repro.apps import fastsv
    a, mesh = build_fastsv(q)
    return fastsv(a, mesh=mesh, max_iters=16, checkpoint_dir=ckpt,
                  elastic=elastic)


def main(mode: str, tmp: str, app: str = "pagerank"):
    if mode == "all":
        return orchestrate(tmp)
    from repro.robust.deadline import TopologyError
    q = grid_for(N_DEV)
    ckpt = os.path.join(tmp, f"ck_{app}")
    out_path = os.path.join(tmp, f"{app}_{mode}.npy")
    if mode == "baseline":
        np.save(out_path, run_app(app, q, None, False))
        print(f"PASS baseline:{app}")
    elif mode == "crash":
        try:
            run_app(app, q, ckpt, False)
        except TopologyError as err:
            print(f"CRASHED {app} ({err})")
            return
        raise SystemExit(f"crash mode finished without TopologyError ({app})")
    elif mode == "resume":
        got = run_app(app, q, ckpt, False)
        ref = np.load(os.path.join(tmp, f"{app}_baseline.npy"))
        np.testing.assert_array_equal(got, ref)
        print(f"PASS resume:{app}")
    elif mode == "live":
        got = run_app(app, q, None, True)
        ref = np.load(os.path.join(tmp, f"{app}_baseline.npy"))
        np.testing.assert_array_equal(got, ref)
        print(f"PASS live:{app}")
    else:
        raise SystemExit(f"unknown mode {mode!r}")


def orchestrate(tmp: str):
    """Run the full crash-and-shrink story for both apps as subprocesses."""
    me = os.path.abspath(__file__)

    def sub(mode, app, ndev, faults=None):
        env = dict(os.environ, REPRO_DEVICES=str(ndev))
        env.pop("XLA_FLAGS", None)
        env.pop("REPRO_FAULTS", None)
        if faults:
            env["REPRO_FAULTS"] = faults
        r = subprocess.run([sys.executable, me, mode, tmp, app], env=env,
                           capture_output=True, text=True, timeout=600)
        sys.stdout.write(r.stdout)
        sys.stderr.write(r.stderr)
        if r.returncode != 0:
            raise SystemExit(f"{mode}:{app} subprocess failed "
                             f"(rc={r.returncode})")
        return r.stdout

    loss = f"loop.device_loss:crash:at={CRASH_AT}"
    for app in ("pagerank", "fastsv"):
        sub("baseline", app, 4)
        out = sub("crash", app, 8, faults=loss)
        assert f"CRASHED {app}" in out, out
        sub("resume", app, 4)               # 2x2 checkpoint -> 1x1 finish
        sub("live", app, 8, faults=loss)    # in-process 2x2 -> 1x1 regrid
    print("PASS elastic-regrid")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], *sys.argv[3:])
