"""repro/dist/shardings: per-arch spec coverage, plan derivation,
divisibility validation, and reproducible parameter init."""
import os
import subprocess
import sys

import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import ARCHS, get_config, get_smoke
from repro.dist.shardings import (ShardingError, ShardingPlan,
                                  spec_for_param, validate_spec,
                                  validate_spec_tree)
from repro.launch.mesh import make_plan
from repro.models.model import init_param_specs, param_shapes

MESH_2D = AbstractMesh((("data", 16), ("model", 16)))
MESH_3D = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def walk(shapes, specs, prefix=""):
    assert isinstance(specs, dict) == isinstance(shapes, dict), prefix
    if isinstance(shapes, dict):
        assert set(specs) == set(shapes), (prefix, set(specs) ^ set(shapes))
        for k in shapes:
            yield from walk(shapes[k], specs[k],
                            f"{prefix}/{k}" if prefix else k)
    else:
        yield prefix, tuple(shapes), specs


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [MESH_2D, MESH_3D],
                         ids=["16x16", "2x16x16"])
def test_specs_congruent_and_divisible(arch, mesh):
    cfg = get_config(arch)
    plan = make_plan(cfg, mesh=mesh)
    sizes = dict(mesh.shape)
    shapes = param_shapes(cfg)
    specs = init_param_specs(cfg, plan)
    n_sharded = 0
    for path, shape, spec in walk(shapes, specs):
        assert isinstance(spec, P), (path, spec)
        assert len(spec) <= len(shape), (path, shape, spec)
        used = []
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for ax in axes:
                assert ax in sizes, (path, ax)       # axis exists on mesh
                assert ax not in used, (path, spec)  # used at most once
                used.append(ax)
                total *= sizes[ax]
            assert shape[d] % total == 0, (path, shape, spec)
            n_sharded += 1
        if path.startswith("blocks/"):
            assert spec[0] is None, (path, spec)     # scanned reps dim
        assert "pod" not in used, (path, spec)       # pod = pure DP
    assert n_sharded > 0
    # the module's own validator agrees
    validate_spec_tree(specs, shapes, plan)


@pytest.mark.parametrize("arch", ARCHS)
def test_every_weight_matrix_is_sharded(arch):
    """No replicated-fallback: every ≥2-D parameter carries at least one
    mesh axis (1-D norm/bias vectors may stay whole)."""
    cfg = get_config(arch)
    plan = make_plan(cfg, mesh=MESH_2D)
    for path, shape, spec in walk(param_shapes(cfg),
                                  init_param_specs(cfg, plan)):
        base = shape[1:] if path.startswith("blocks/") else shape
        if len(base) >= 2:
            assert any(e is not None for e in spec), (path, shape, spec)


def test_unknown_param_fails_loudly():
    cfg = get_config("qwen2-72b")
    plan = make_plan(cfg, mesh=MESH_2D)
    with pytest.raises(ShardingError, match="no sharding rule"):
        spec_for_param("blocks/pos0/mystery_w", (4, 16, 16), cfg, plan)
    with pytest.raises(ShardingError, match="no sharding rule"):
        spec_for_param("mystery_top", (16, 16), cfg, plan)


def test_indivisible_dim_fails_loudly():
    cfg = get_config("qwen2-72b")
    plan = make_plan(cfg, mesh=MESH_2D)
    with pytest.raises(ShardingError, match="not divisible"):
        spec_for_param("blocks/pos0/wq", (1, 8192, 100), cfg, plan)
    with pytest.raises(ShardingError, match="not divisible"):
        validate_spec(P("model"), (100,), plan, "x")
    with pytest.raises(ShardingError, match="not on this plan's mesh"):
        validate_spec(P("bogus_axis"), (16,), plan, "x")
    with pytest.raises(ShardingError, match="two dims"):
        validate_spec(P("model", "model"), (16, 16), plan, "x")


def test_plan_derived_from_mesh_shape():
    """dp_size / model_size follow the mesh — no hard-coded 32/16/16."""
    cfg = get_config("granite-3-2b")
    small = AbstractMesh((("data", 4), ("model", 2)))
    plan = make_plan(cfg, mesh=small)
    assert plan.dp_size == 4 and plan.model_size == 2
    assert plan.dp_axes == ("data",) and plan.fsdp_axes == ("data",)
    tri = AbstractMesh((("pod", 3), ("data", 4), ("model", 2)))
    plan3 = make_plan(cfg, mesh=tri)
    assert plan3.dp_size == 12 and plan3.dp_axes == ("pod", "data")
    assert plan3.fsdp_axes == ("data",)      # pod stays pure DP
    assert plan3.dp() == ("pod", "data")
    # production fallback without a mesh keeps the paper grids
    assert make_plan(cfg).dp_size == 16
    assert make_plan(cfg, multi_pod=True).dp_size == 32
    with pytest.raises(ValueError, match="model"):
        make_plan(cfg, mesh=AbstractMesh((("a", 4), ("b", 2))))


def test_context_parallel_cache_layout():
    cfg = get_config("mamba2-2.7b")
    # decode with batch < dp: sequence-sharded cache, unsharded batch
    plan = make_plan(cfg, shape_kind="decode", batch=1, mesh=MESH_2D)
    assert plan.context_parallel
    assert plan.cache_spec("kv", dict(kvh=8, hd=128)) == \
        (None, "data", None, "model")
    assert plan.cache_spec("ssm", dict(h=80)) == \
        (None, "model", None, None)
    assert plan.act_spec() == P(None, None, None)
    # decode with batch ≥ dp: batch-sharded cache
    plan = make_plan(cfg, shape_kind="decode", batch=128, mesh=MESH_2D)
    assert not plan.context_parallel
    assert plan.cache_spec("kv", dict(kvh=8, hd=128)) == \
        ("data", None, None, "model")
    # GQA head count ≥ model size shards heads, not head_dim
    assert plan.cache_spec("kv", dict(kvh=16, hd=128)) == \
        ("data", None, "model", None)
    assert plan.cache_spec("kv_flat", dict(x=512)) == \
        ("data", None, "model")
    assert plan.cache_spec("conv", dict(c=5376)) == \
        ("data", None, "model")
    with pytest.raises(ShardingError, match="cache kind"):
        plan.cache_spec("bogus", {})


def test_moe_ep_regroups_expert_weights():
    cfg = get_config("qwen2-moe-a2.7b")
    plan = make_plan(cfg, mesh=MESH_2D)
    ep = make_plan(cfg, mesh=MESH_2D, moe_ep=True)
    E = 64                                   # 60 routed padded to 64
    shp = (1, E, cfg.d_model, cfg.d_ff)
    assert spec_for_param("blocks/pos0/we_g", shp, cfg, plan) == \
        P(None, "model", None, "data")
    # EP regrouping: weights stay whole per expert shard (shard_map
    # consumes P('model', None, None))
    assert spec_for_param("blocks/pos0/we_g", shp, cfg, ep) == \
        P(None, "model", None, None)
    assert ep.ep_spec() == P("model", None, None)


def test_serving_layout_drops_fsdp():
    import dataclasses
    cfg = get_config("qwen2-72b")
    plan = dataclasses.replace(make_plan(cfg, mesh=MESH_2D), fsdp_axes=())
    spec = spec_for_param("blocks/pos0/wq", (1, 8192, 8192), cfg, plan)
    assert spec == P(None, None, "model")
    assert spec_for_param("blocks/pos0/ln1", (1, 8192), cfg, plan) == \
        P(None, None)


def test_smoke_configs_shard_on_small_mesh():
    """The same rules hold for the reduced smoke configs on a test-sized
    mesh (every smoke dim divides 2×2)."""
    mesh = AbstractMesh((("data", 2), ("model", 2)))
    for arch in ARCHS:
        cfg = get_smoke(arch)
        plan = make_plan(cfg, mesh=mesh)
        validate_spec_tree(init_param_specs(cfg, plan), param_shapes(cfg),
                           plan)


_INIT_DIGEST = r"""
import numpy as np
from repro.configs.registry import get_smoke
from repro.models.model import init_params
params = init_params(get_smoke("jamba-1.5-large-398b"), seed=3)
acc = 0.0
def fold(t, pre=""):
    global acc
    for k in sorted(t):
        v = t[k]
        if isinstance(v, dict):
            fold(v, pre + k + "/")
        else:
            acc += float(np.abs(np.asarray(v, np.float64)).sum())
fold(params)
print(f"{acc:.10e}")
"""


def test_init_reproducible_across_hash_seeds():
    """init_params must not depend on Python's per-process hash salt:
    two processes with different PYTHONHASHSEED get identical params."""
    root = os.path.join(os.path.dirname(__file__), "..")
    outs = []
    for hs in ("0", "424242"):
        env = dict(os.environ, PYTHONHASHSEED=hs,
                   PYTHONPATH=os.path.join(root, "src"))
        proc = subprocess.run([sys.executable, "-c", _INIT_DIGEST],
                              capture_output=True, text=True, env=env,
                              cwd=root, timeout=300)
        assert proc.returncode == 0, proc.stderr[-2000:]
        outs.append(proc.stdout.strip())
    assert outs[0] == outs[1], outs
