"""Overlapped vs serial distributed-SpGEMM schedules (§4.8).

The multi-device half runs tests/dist_overlap_scenarios.py in a subprocess
on a REPRO_DEVICES=8 mesh (2x2 grid — the CI bench-smoke mesh): bitwise
oracle equality of overlap=True vs overlap=False across schedule × merge ×
masked/unmasked combos, cross-schedule equivalence, the 3D fused
all-to-all, and int8-compressed exchanges (error bounds + batched error
feedback). The in-process half property-tests dist/compression.py's
quantize_payload on semiring value buffers — no devices needed.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.semiring import ARITHMETIC
from repro.dist.compression import dequantize_payload, quantize_payload

HERE = os.path.dirname(__file__)
SCRIPT = os.path.join(HERE, "dist_overlap_scenarios.py")

GROUPS = {
    "rotate": ["overlap_bitwise_rotate"],
    "alltoall": ["overlap_bitwise_alltoall"],
    "bcast": ["overlap_bitwise_bcast"],
    "hybrid": ["overlap_bitwise_hybrid", "schedule_equivalence"],
    "3d": ["overlap_bitwise_3d"],
    "compressed": ["compressed_exchange", "compressed_batched_feedback",
                   "compress_rejects_bad_semiring"],
}


def run_scenarios(names):
    env = dict(os.environ, REPRO_DEVICES="8")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, SCRIPT] + names,
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, \
        f"scenarios {names} failed:\n{proc.stdout}\n{proc.stderr}"
    for n in names:
        assert "PASS" in proc.stdout


@pytest.mark.parametrize("group", sorted(GROUPS), ids=str)
def test_overlap_group(group):
    run_scenarios(GROUPS[group])


# --------------------------------------------------------------------------
# quantize_payload property tests (in-process, single device)
# --------------------------------------------------------------------------

def _tiles(seed, shape=(2, 2), cap=64):
    """Random COO-style value buffers with live prefixes + identity padding."""
    rng = np.random.default_rng(seed)
    nnz = rng.integers(0, cap + 1, shape).astype(np.int32)
    val = (rng.standard_normal(shape + (cap,)) * 10).astype(np.float32)
    live = np.arange(cap) < nnz[..., None]
    val = np.where(live, val, np.float32(ARITHMETIC.add.identity))
    return val, nnz, live


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_quantize_roundtrip_error_bound(seed):
    """|val − deq| ≤ scale/2 per live entry; padding is exactly 0 int8."""
    val, nnz, live = _tiles(seed)
    q8, scale, resid = quantize_payload(val, nnz)
    q8, scale, resid = map(np.asarray, (q8, scale, resid))
    assert q8.dtype == np.int8 and scale.dtype == val.dtype
    assert np.all(q8[~live] == 0) and np.all(resid[~live] == 0)
    deq = np.asarray(dequantize_payload(q8, scale))
    err = np.abs(val - deq)
    # scale/2 plus one ulp of the scale multiply
    bound = scale[..., None] / 2 + np.abs(deq) * 1e-6
    assert np.all(err[live] <= bound[live] + 1e-30)
    # the scale never exceeds max live |val| / 127 (no padding inflation)
    mx = np.max(np.abs(np.where(live, val, 0)), axis=-1)
    assert np.all(scale <= np.maximum(mx / 127, 1e-30) * (1 + 1e-6))


@pytest.mark.parametrize("seed", [5, 6])
def test_quantize_error_feedback_exact(seed):
    """deq + new_resid == val + resid EXACTLY (the EF mass contract), and
    feeding the residual back keeps the error from accumulating."""
    val, nnz, live = _tiles(seed)
    resid = None
    for _ in range(4):
        q8, scale, resid_new = quantize_payload(val, nnz, resid)
        e = val if resid is None else val + np.asarray(resid)
        deq = np.asarray(dequantize_payload(q8, scale))
        np.testing.assert_array_equal(
            (deq + np.asarray(resid_new))[live], e[live],
            err_msg="EF mass not preserved exactly")
        # residual stays within one quantization step — no accumulation
        step = np.broadcast_to(np.asarray(scale)[..., None], live.shape)
        assert np.all(np.abs(np.asarray(resid_new))[live]
                      <= step[live] / 2 * (1 + 1e-6))
        resid = resid_new


def test_quantize_all_padding_tile():
    """An empty tile (nnz=0) quantizes to all-zero int8 with a benign scale."""
    val = np.zeros((1, 1, 16), np.float32)
    nnz = np.zeros((1, 1), np.int32)
    q8, scale, resid = quantize_payload(val, nnz)
    assert np.all(np.asarray(q8) == 0)
    assert np.all(np.asarray(resid) == 0)
    assert np.all(np.asarray(scale) > 0)   # clipped away from 0 — deq-safe
