"""Elastic topology recovery units: watchdog, exchange deadlines, regrid,
mesh-independent sparse checkpoints, schedule demotion, straggler re-plan.

The end-to-end crash-and-shrink story (8 devices -> crash -> resume on 4,
bitwise) lives in elastic_regrid_scenario.py (subprocess; CI chaos-smoke).
Everything here runs on the default single-device test environment —
multi-grid containers are exercised host-side (``mesh=None``), which is the
same assembly/extraction code path shard_put would wrap.
"""
import os
import time

import numpy as np
import pytest

from repro.core import DistSpMat, make_grid
from repro.core.dist import DistSpMat3D, restore_spmat, save_spmat
from repro.launch.elastic import StepWatchdog
from repro.robust import deadline, faults
from repro.robust.deadline import ExchangeGuard, ExchangeTimeout, \
    TopologyError
from repro.robust.recover import CheckpointedLoop


def _coo(n=48, density=0.08, seed=0, vdtype=np.float32):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, n)) < density)
    r, c = np.nonzero(dense)
    v = rng.standard_normal(len(r)).astype(vdtype)
    return (n, n), r.astype(np.int64), c.astype(np.int64), v


# --------------------------------------------------------------------------
# StepWatchdog (launch/elastic.py) — direct unit tests
# --------------------------------------------------------------------------

class TestStepWatchdog:
    def test_warmup_no_budget(self):
        wd = StepWatchdog(grace=2.0, window=8, min_samples=3)
        for t in (1.0, 1.0):
            wd.times.append(t)
        assert wd.budget() is None            # < min_samples: still warmup
        assert not wd.is_straggling(100.0)    # never flags during warmup
        wd.times.append(1.0)
        assert wd.budget() == pytest.approx(2.0)
        assert wd.is_straggling(2.5)
        assert not wd.is_straggling(1.5)

    def test_window_eviction(self):
        wd = StepWatchdog(grace=1.0, window=4, min_samples=2)
        for t in (9.0, 9.0, 9.0, 9.0):
            wd.times.append(t)
        assert wd.budget() == pytest.approx(9.0)
        for t in (1.0, 1.0, 1.0, 1.0):        # maxlen=4 evicts the 9s
            wd.times.append(t)
        assert wd.budget() == pytest.approx(1.0)

    def test_reset_returns_to_warmup(self):
        wd = StepWatchdog(min_samples=2)
        wd.start()
        wd.stop()
        wd.times.append(0.5)
        assert wd.budget() is not None
        wd.reset()
        assert wd.budget() is None
        assert len(wd.times) == 0
        assert wd._t0 is None


# --------------------------------------------------------------------------
# ExchangeGuard (robust/deadline.py)
# --------------------------------------------------------------------------

class TestExchangeGuard:
    def test_startup_budget_until_min_samples(self):
        g = ExchangeGuard(min_samples=3, startup_deadline=7.0, grace=2.0,
                          floor=0.0)
        assert g.budget("s") == 7.0
        g.record("s", 0.1)
        g.record("s", 0.1)
        assert g.budget("s") == 7.0           # 2 < min_samples
        g.record("s", 0.1)
        assert g.budget("s") == pytest.approx(0.2)

    def test_floor_and_median(self):
        g = ExchangeGuard(min_samples=1, grace=4.0, floor=1.0)
        g.record("s", 1e-5)
        assert g.budget("s") == 1.0           # floor wins over 4x median
        for _ in range(5):
            g.record("s", 2.0)
        assert g.budget("s") == pytest.approx(8.0)

    def test_trip_raises_and_is_not_recorded(self):
        g = ExchangeGuard(min_samples=1, startup_deadline=0.005)
        with pytest.raises(ExchangeTimeout) as ei:
            with g.watch("site.x"):
                time.sleep(0.03)
        assert ei.value.site == "site.x"
        assert ei.value.elapsed > ei.value.budget_s
        assert g.samples("site.x") == 0       # straggler must not poison
        # AuditError subclass: the planner retry machinery catches it
        from repro.robust.audit import AuditError
        assert isinstance(ei.value, AuditError)

    def test_good_exchanges_recorded(self):
        g = ExchangeGuard(startup_deadline=30.0)
        for _ in range(3):
            with g.watch("site.y"):
                pass
        assert g.samples("site.y") == 3

    def test_reset_one_site_and_all(self):
        g = ExchangeGuard()
        g.record("a", 1.0)
        g.record("b", 1.0)
        g.reset("a")
        assert g.samples("a") == 0 and g.samples("b") == 1
        g.record("a", 1.0)
        g.reset()
        assert g.samples("a") == 0 and g.samples("b") == 0

    def test_backoff_deterministic_and_bounded(self):
        g = ExchangeGuard(backoff_base=0.05, backoff_cap=5.0)
        d1 = g.backoff_delay("site.x", 1)
        assert d1 == g.backoff_delay("site.x", 1)          # seeded
        assert d1 != g.backoff_delay("site.x", 2)          # attempt-keyed
        assert d1 != g.backoff_delay("site.z", 1)          # site-keyed
        for attempt in (1, 2, 3, 8):
            base = min(5.0, 0.05 * 2 ** (attempt - 1))
            d = g.backoff_delay("site.x", attempt)
            assert 0.5 * base <= d <= 1.5 * base

    def test_configure_scope_and_off(self):
        with deadline.configure(startup_deadline=0.25) as g:
            assert deadline.active_guard() is g
            assert g.startup_deadline == 0.25
            with deadline.configure(off=True):
                assert not deadline.enabled()
                with deadline.watch("nope"):   # no-op when off
                    time.sleep(0.0)
            assert deadline.active_guard() is g

    def test_fault_fires_inside_timed_region(self):
        # an armed straggler at dist.exchange_deadline is seen exactly as a
        # slow wire: the watch times it and trips
        with deadline.configure(startup_deadline=0.01):
            with faults.inject("dist.exchange_deadline:delay:amount=0.05"):
                with pytest.raises(ExchangeTimeout):
                    with deadline.watch("site.w"):
                        pass


# --------------------------------------------------------------------------
# regrid: live grid shrink, bitwise
# --------------------------------------------------------------------------

class TestRegrid2D:
    @pytest.mark.parametrize("new_grid", [(2, 2), (1, 1)])
    def test_shrink_bitwise(self, new_grid):
        shape, r, c, v = _coo(seed=1)
        a = DistSpMat.from_global_coo(shape, r, c, v, (4, 4))
        b = a.regrid(new_grid)
        assert b.grid == new_grid
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_grow_bitwise(self):
        shape, r, c, v = _coo(seed=2)
        a = DistSpMat.from_global_coo(shape, r, c, v, (1, 1))
        b = a.regrid((3, 3))
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    @pytest.mark.parametrize("tag", ["row", "col"])
    def test_order_tag_preserved(self, tag):
        shape, r, c, v = _coo(seed=3)
        a = DistSpMat.from_global_coo(shape, r, c, v, (2, 2), order=tag)
        assert a.order == tag
        b = a.regrid((1, 1))
        assert b.order == tag
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_cap_replanned_and_override(self):
        shape, r, c, v = _coo(seed=4)
        a = DistSpMat.from_global_coo(shape, r, c, v, (4, 4))
        b = a.regrid((1, 1))          # 1 tile holds ALL entries now
        assert b.cap >= len(r)
        assert b.regrid((1, 1), cap=4096).cap == 4096

    def test_empty_matrix(self):
        a = DistSpMat.from_global_coo(
            (32, 32), np.zeros(0, np.int64), np.zeros(0, np.int64),
            np.zeros(0, np.float32), (2, 2))
        b = a.regrid((1, 1))
        assert int(np.asarray(b.nnz).sum()) == 0
        assert b.shape == (32, 32)

    def test_on_mesh(self):
        # the single-device grid still round-trips through shard_put
        shape, r, c, v = _coo(seed=5)
        mesh = make_grid(1, 1)
        a = DistSpMat.from_global_coo(shape, r, c, v, (1, 1), mesh=mesh)
        b = a.regrid((1, 1), mesh=mesh)
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())


class TestRegrid3D:
    def test_layer_shrink_bitwise(self):
        shape, r, c, v = _coo(n=60, seed=6)
        a = DistSpMat3D.from_global_coo(shape, r, c, v, (2, 2, 2), "acol")
        b = a.regrid((1, 2, 2))
        assert b.grid == (1, 2, 2) and b.dist == "acol"
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_dist_override(self):
        shape, r, c, v = _coo(n=60, seed=7)
        a = DistSpMat3D.from_global_coo(shape, r, c, v, (2, 2, 2), "brow")
        b = a.regrid((2, 1, 1), dist="csub")
        assert b.dist == "csub"
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())


# --------------------------------------------------------------------------
# mesh-independent sparse checkpoints (core/dist.py <-> train/checkpoint.py)
# --------------------------------------------------------------------------

class TestSparseCheckpoint:
    def test_roundtrip_cross_grid_2d(self, tmp_path):
        shape, r, c, v = _coo(seed=8)
        a = DistSpMat.from_global_coo(shape, r, c, v, (4, 4), order="col")
        save_spmat(str(tmp_path), 7, a)
        # restore onto a SMALLER grid than the one that saved
        b, step = restore_spmat(str(tmp_path), (2, 2))
        assert step == 7
        assert b.grid == (2, 2)
        assert b.order == "col"               # tag rides through the bytes
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_roundtrip_3d_and_layer_loss(self, tmp_path):
        shape, r, c, v = _coo(n=60, seed=9)
        a = DistSpMat3D.from_global_coo(shape, r, c, v, (2, 2, 2), "brow")
        save_spmat(str(tmp_path), 3, a)
        b, step = restore_spmat(str(tmp_path), (1, 2, 2))
        assert step == 3
        assert b.grid == (1, 2, 2) and b.dist == "brow"
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_restore_3d_checkpoint_as_2d(self, tmp_path):
        # the state is mesh-independent global COO: the container family is
        # the RESTORER's choice, not baked into the checkpoint
        shape, r, c, v = _coo(n=60, seed=10)
        a = DistSpMat3D.from_global_coo(shape, r, c, v, (2, 2, 2), "acol")
        save_spmat(str(tmp_path), 0, a)
        b, _ = restore_spmat(str(tmp_path), (2, 2))
        assert isinstance(b, DistSpMat)
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_crc_manifest_path(self, tmp_path):
        # rides train/checkpoint.py: manifest + per-leaf npy exist
        shape, r, c, v = _coo(seed=11)
        a = DistSpMat.from_global_coo(shape, r, c, v, (2, 2))
        save_spmat(str(tmp_path), 12, a)
        stepdir = tmp_path / "step_00000012"
        assert (stepdir / "manifest.json").exists()
        assert any(f.suffix == ".npy" for f in stepdir.iterdir())


# --------------------------------------------------------------------------
# hybrid-schedule demotion (core/plan.demote_stage)
# --------------------------------------------------------------------------

class TestDemoteStage:
    def _plan(self, schedule=None, q=4):
        from repro.core.plan import SpGEMMPlan
        return SpGEMMPlan(prod_cap=64, out_cap=64, variant="rotation",
                          merge="sort", prod_ceiling=1 << 20,
                          out_ceiling=1 << 20, est_flops=1.0, est_out=1.0,
                          schedule=schedule)

    def test_expands_whole_sweep_schedule(self):
        from repro.core.plan import demote_stage
        p = self._plan(schedule=None)
        with pytest.warns(RuntimeWarning, match="demoting exchange stage"):
            p2 = demote_stage(p, 2, 4)
        assert p2.schedule == ("bcast", "bcast", "gather", "bcast")
        assert p2.variant == "hybrid"
        assert "demote-stage:2" in p2.degraded

    def test_tuple_schedule_and_idempotence(self):
        from repro.core.plan import demote_stage
        p = self._plan(schedule=("bcast", "gather", "bcast", "bcast"))
        with pytest.warns(RuntimeWarning):
            p2 = demote_stage(p, 0, 4)
        assert p2.schedule == ("gather", "gather", "bcast", "bcast")
        assert demote_stage(p2, 1, 4) is p2   # already gather: no-op

    def test_stage_bounds(self):
        from repro.core.plan import demote_stage
        with pytest.raises(ValueError):
            demote_stage(self._plan(), 4, 4)
        with pytest.raises(ValueError):
            demote_stage(self._plan(schedule=("bcast",) * 3), 0, 4)


# --------------------------------------------------------------------------
# CheckpointedLoop: topology events + persistent stragglers
# --------------------------------------------------------------------------

class TestElasticLoop:
    @staticmethod
    def _counting_body(log):
        def body(it, state):
            log.append(it)
            return {"x": np.asarray(state["x"]) + 1}, False
        return body

    def test_device_loss_without_hook_raises(self):
        loop = CheckpointedLoop()
        with faults.inject("loop.device_loss:crash:at=3"):
            with pytest.raises(TopologyError):
                loop.run({"x": np.int64(0)}, self._counting_body([]), 8)

    def test_device_loss_with_hook_reruns_same_iteration(self):
        seen, hook = [], []
        loop = CheckpointedLoop(
            on_topology=lambda s, e: (hook.append(e), s)[1])
        with faults.inject("loop.device_loss:crash:at=3"):
            state = loop.run({"x": np.int64(0)}, self._counting_body(seen), 5)
        # activation 3 fires at iteration 2 BEFORE body runs; the hook
        # regrids and the same iteration re-runs: no iteration is skipped
        assert seen == [0, 1, 2, 3, 4]
        assert int(state["x"]) == 5
        assert len(hook) == 1 and hook[0].site == "loop.device_loss"

    def test_max_topology_events_rethrows(self):
        loop = CheckpointedLoop(on_topology=lambda s, e: s,
                                max_topology_events=1)
        with faults.inject("loop.device_loss:crash:at=2,count=3"):
            with pytest.raises(TopologyError):
                loop.run({"x": np.int64(0)}, self._counting_body([]), 8)

    def test_checkpoint_then_resume_after_loss(self, tmp_path):
        ck = str(tmp_path / "ck")
        seen = []
        loop = CheckpointedLoop(ck)
        with faults.inject("loop.device_loss:crash:at=4"):
            with pytest.raises(TopologyError):
                loop.run({"x": np.int64(0)}, self._counting_body(seen), 6)
        assert seen == [0, 1, 2]              # died entering iteration 3
        # a fresh process (smaller topology) resumes: redoes it 3 onward
        state = CheckpointedLoop(ck).run({"x": np.int64(0)},
                                         self._counting_body(seen), 6)
        assert seen == [0, 1, 2, 3, 4, 5]
        assert int(state["x"]) == 6

    def test_straggler_triggers_replan_with_real_watchdog(self):
        wd = StepWatchdog(grace=1.5, window=8, min_samples=2)
        calls = []
        loop = CheckpointedLoop(watchdog=wd, straggler_patience=1,
                                on_straggler=lambda it, dt: calls.append(it))
        with faults.inject("loop.delay:delay:amount=0.12,at=3,count=5"):
            with pytest.warns(RuntimeWarning, match="straggling"):
                loop.run({"x": np.int64(0)}, self._counting_body([]), 7)
        # first over-budget iteration re-plans; the reset re-learns the
        # (now slow) timing, so the later delayed iterations don't re-fire
        assert calls == [2]
        assert len(wd.times) < wd.min_samples or not wd.is_straggling(0.12)

    def test_straggler_patience_counts_consecutive_only(self):
        class ScriptedWD:
            """stop() returns the scripted dt; >1.0 counts as straggling."""
            def __init__(self, dts):
                self.dts = list(dts)
                self.resets = 0

            def start(self):
                pass

            def stop(self):
                return self.dts.pop(0)

            def budget(self):
                return 1.0

            def is_straggling(self, dt):
                return dt > 1.0

            def reset(self):
                self.resets += 1

        # straggle, clean, straggle, straggle: only the CONSECUTIVE pair
        # reaches patience=2 — the clean iteration resets the count
        wd = ScriptedWD([5.0, 0.1, 5.0, 5.0, 0.1])
        calls = []
        loop = CheckpointedLoop(watchdog=wd, straggler_patience=2,
                                on_straggler=lambda it, dt: calls.append(it))
        with pytest.warns(RuntimeWarning, match="straggling"):
            loop.run({"x": np.int64(0)}, self._counting_body([]), 5)
        assert calls == [3]
        assert wd.resets == 1
