"""Graceful degradation when hypothesis is not installed.

``pytest.importorskip("hypothesis")`` at module scope would skip entire
files, losing every deterministic oracle test that happens to share a module
with a property test. Instead, test modules do

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st

and ONLY the ``@given``-decorated property tests skip (each one calls
``pytest.importorskip`` at run time, so the skip reason points at
requirements-dev.txt).
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper(self=None, *a, **k):
            pytest.importorskip(
                "hypothesis",
                reason="property test needs hypothesis "
                       "(pip install -r requirements-dev.txt)")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategy:
    """Accepts any strategy construction; never actually draws."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategy()
