"""Flight-recorder tests (obs/): disabled-mode fast path, span nesting and
thread-safety, Chrome-trace schema, deadline.stats (the public window view),
counter determinism across seeded subprocess runs, and the chaos test — an
injected compressed-exchange fault must surface as audit + ladder events in
the trace, with no stderr scraping.
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import ARITHMETIC, make_grid, DistSpMat
from repro.core.plan import spgemm as spgemm_planned
from repro.obs import recorder
from repro.robust import audit, deadline, faults


@pytest.fixture(scope="module")
def mesh():
    return make_grid(1, 1)


def make_graph(n=40, density=0.3, seed=0):
    rng = np.random.default_rng(seed)
    dense = np.where(rng.random((n, n)) < density,
                     rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
    r, c = np.nonzero(dense)
    return dense, (r.astype(np.int64), c.astype(np.int64),
                   dense[r, c].astype(np.float32))


# --------------------------------------------------------------------------
# disabled mode: the near-zero-overhead contract
# --------------------------------------------------------------------------

class TestDisabled:
    def test_disabled_span_is_shared_noop(self):
        assert not obs.enabled()
        s1 = obs.span("x", a=1)
        s2 = obs.span("y")
        assert s1 is s2 is recorder._NOOP      # no allocation per call

    def test_disabled_records_nothing(self):
        obs.counter_add("c", 5)
        obs.event("e", k=1)
        with obs.span("s"):
            pass
        assert obs.counters() == {}
        assert obs.events() == []
        assert obs.snapshot()["spans"] == {}

    def test_disabled_timed_calls_through(self):
        calls = []

        @obs.timed("t")
        def fn(x):
            calls.append(x)
            return x + 1

        assert fn(1) == 2 and calls == [1]
        assert obs.snapshot()["spans"] == {}

    def test_sync_passthrough_when_disabled(self):
        x = object()
        assert obs.sync(x) is x

    def test_disabled_overhead_under_1pct(self):
        # the acceptance bound is <1% on spgemm_local; a pure-python probe
        # bounds the per-call cost far below any kernel's wall time
        def bare():
            return sum(range(50))

        @obs.timed("probe")
        def probed():
            return sum(range(50))

        n = 20000
        for f in (bare, probed):      # warm
            for _ in range(200):
                f()
        t0 = time.perf_counter()
        for _ in range(n):
            bare()
        t_bare = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(n):
            probed()
        t_probed = time.perf_counter() - t0
        # generous CI bound: the disabled wrapper is one boolean read
        assert t_probed < t_bare * 2.0, (t_bare, t_probed)


# --------------------------------------------------------------------------
# recording: nesting, thread-safety, capture scoping
# --------------------------------------------------------------------------

class TestSpans:
    def test_nesting_depths(self):
        with obs.capture() as rec:
            with obs.span("outer"):
                with obs.span("inner"):
                    time.sleep(0.001)
            snap = rec.snapshot()
            evs = rec.trace_events()
        assert set(snap["spans"]) == {"outer", "inner"}
        byname = {e["name"]: e for e in evs if e.get("cat") == "span"}
        o, i = byname["outer"], byname["inner"]
        assert o["ts"] <= i["ts"]
        assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3

    def test_span_attrs_exported(self):
        with obs.capture() as rec:
            with obs.span("s", schedule="rotate", q=2, flag=True):
                pass
            evs = rec.trace_events()
        (e,) = [x for x in evs if x.get("cat") == "span"]
        assert e["args"] == {"schedule": "rotate", "q": 2, "flag": True}

    def test_thread_safety(self):
        nthreads, per = 8, 50

        def work(k):
            for i in range(per):
                with obs.span(f"t{k}"):
                    obs.counter_add("ops")

        with obs.capture() as rec:
            ts = [threading.Thread(target=work, args=(k,))
                  for k in range(nthreads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            snap = rec.snapshot()
        assert snap["counters"]["ops"] == nthreads * per
        for k in range(nthreads):
            assert snap["spans"][f"t{k}"]["count"] == per

    def test_capture_restores_prior_state(self):
        assert not obs.enabled()
        with obs.capture():
            assert obs.enabled()
            obs.counter_add("x", 1)
        assert not obs.enabled()
        assert obs.counters() == {}

    def test_out_of_order_exit(self):
        with obs.capture() as rec:
            a = obs.span("a")
            b = obs.span("b")
            a.__enter__()
            b.__enter__()
            a.__exit__(None, None, None)
            b.__exit__(None, None, None)
            snap = rec.snapshot()
        assert set(snap["spans"]) == {"a", "b"}

    def test_coverage(self):
        with obs.capture() as rec:
            with obs.span("parent"):
                with obs.span("child"):
                    time.sleep(0.005)
            cov = rec.coverage("parent")
        assert 0.5 < cov <= 1.0


# --------------------------------------------------------------------------
# Chrome-trace schema
# --------------------------------------------------------------------------

class TestTraceSchema:
    def test_trace_file_schema(self, tmp_path):
        path = str(tmp_path / "trace.json")
        with obs.capture() as rec:
            with obs.span("s", k="v"):
                obs.counter_add("bytes", 128)
            obs.event("decision", rung="serial-schedule")
            rec.write_trace(path)
        doc = json.load(open(path))
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert doc["displayTimeUnit"] == "ms"
        assert {"epoch_unix_s", "pid"} <= set(doc["otherData"])
        evs = doc["traceEvents"]
        phases = {e["ph"] for e in evs}
        assert {"X", "C", "i", "M"} <= phases
        for e in evs:
            assert {"name", "ph", "pid", "tid"} <= set(e)
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] >= 0
                assert e["cat"] == "span"
            if e["ph"] == "i":
                assert e["s"] == "t" and e["cat"] == "event"
            if e["ph"] == "C":
                assert "value" in e["args"]
        # instant event payload survives export as plain JSON
        (inst,) = [e for e in evs if e["ph"] == "i"]
        assert inst["args"]["rung"] == "serial-schedule"

    def test_nonjson_attrs_stringified(self):
        with obs.capture() as rec:
            with obs.span("s", obj=np.int64(3), tup=("a", "b")):
                pass
            evs = rec.trace_events()
        (e,) = [x for x in evs if x.get("cat") == "span"]
        json.dumps(e)                              # must be serializable
        assert e["args"]["tup"] == "('a', 'b')"


# --------------------------------------------------------------------------
# deadline.stats — the public window view (satellite 1)
# --------------------------------------------------------------------------

class TestDeadlineStats:
    def test_stats_empty_site(self):
        g = deadline.ExchangeGuard(startup_deadline=1.0)
        st = g.stats("never-seen")
        # warmup: no samples yet, budget falls back to the startup deadline
        assert st == {"n": 0, "median_s": None, "budget_s": 1.0, "trips": 0}

    def test_stats_tracks_window_and_budget(self):
        g = deadline.ExchangeGuard(startup_deadline=1.0)
        for _ in range(5):
            with g.watch("site.a"):
                time.sleep(0.001)
        st = g.stats("site.a")
        assert st["n"] == 5
        assert st["median_s"] == pytest.approx(0.001, rel=5.0)
        assert st["budget_s"] > 0
        assert st["trips"] == 0
        assert g.sites() == ["site.a"]

    def test_trips_counted_and_survive_reset(self):
        g = deadline.ExchangeGuard(startup_deadline=0.001)
        with pytest.raises(deadline.ExchangeTimeout):
            with g.watch("site.b"):
                time.sleep(0.01)
        assert g.stats("site.b")["trips"] == 1
        g.reset()
        assert g.stats("site.b")["trips"] == 1    # trips survive reset
        assert g.stats("site.b")["n"] == 0        # samples do not

    def test_module_level_stats(self):
        with deadline.configure(startup_deadline=1.0):
            with deadline.watch("site.c"):
                pass
            assert deadline.stats("site.c")["n"] == 1
            assert "site.c" in deadline.sites()
        with deadline.configure(off=True):
            assert deadline.stats("site.c") == \
                {"n": 0, "median_s": None, "budget_s": None, "trips": 0}
            assert deadline.sites() == []

    def test_trip_emits_obs_event(self):
        g = deadline.ExchangeGuard(startup_deadline=0.001)
        with obs.capture() as rec:
            with pytest.raises(deadline.ExchangeTimeout):
                with g.watch("site.d"):
                    time.sleep(0.01)
            evs = rec.events("deadline.trip")
            ctr = rec.counters()
        assert len(evs) == 1 and evs[0]["site"] == "site.d"
        assert evs[0]["elapsed_s"] > evs[0]["budget_s"]
        assert ctr["deadline.trips"] == 1

    def test_snapshot_includes_deadline_section(self):
        with deadline.configure(startup_deadline=1.0):
            with obs.capture() as rec:
                with deadline.watch("site.e"):
                    pass
                snap = rec.snapshot()
        assert snap["deadline"]["site.e"]["n"] == 1


# --------------------------------------------------------------------------
# engine integration: spans + counters from a real planned multiply
# --------------------------------------------------------------------------

class TestEngineIntegration:
    def test_spgemm_planned_records(self, mesh):
        _, (r, c, v) = make_graph(seed=1)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        with obs.capture() as rec:
            spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
            snap = rec.snapshot()
            # coverage reads live buffers — compute before capture() exits
            cov = rec.coverage("spgemm2d")
        assert "spgemm2d" in snap["spans"]
        assert "spgemm2d.execute" in snap["spans"]
        assert snap["events"].get("plan.spgemm") == 1
        comm = [k for k in snap["counters"] if k.startswith("comm.bytes.")]
        assert comm, snap["counters"]
        # per-stage spans account for >=90% of the wrapper span
        assert cov >= 0.9, cov

    def test_payload_nbytes_matches_live_entries(self, mesh):
        _, (r, c, v) = make_graph(seed=2)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        nnz = int(np.sum(np.asarray(A.nnz)))
        # int32 row + int32 col + f32 val = 12 bytes per live entry
        assert audit.payload_nbytes(A) == nnz * 12

    def test_chaos_fault_lands_in_trace(self, mesh):
        """An injected compressed-exchange fault must be visible in the
        flight recorder alone: audit.failure + retry events in obs, and in
        the exported Chrome trace — no stderr scraping (satellite)."""
        _, (r, c, v) = make_graph(seed=3)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        with obs.capture() as rec:
            with audit.at_level("boundary"), \
                    faults.inject("dist.compressed_exchange:corrupt_val"), \
                    pytest.warns(RuntimeWarning, match="failed audit"):
                _, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                         compress="int8")
            assert plan.attempts == 2
            fails = rec.events("audit.failure")
            retries = rec.events("plan.audit_retry")
            ctr = rec.counters()
            evs = rec.trace_events()
        assert any(f["site"] == "dist.compressed_exchange" for f in fails)
        assert retries and retries[0]["op"] == "spgemm"
        assert ctr["audit.failures"] >= 1
        assert ctr["plan.audit_retries"] >= 1
        names = {e["name"] for e in evs if e["ph"] == "i"}
        assert {"audit.failure", "plan.audit_retry"} <= names

    def test_ladder_rung_mirrored_as_event(self, mesh):
        """Persistent corruption walks the ladder; the RuntimeWarning is
        mirrored as a ladder.rung obs event (satellite)."""
        _, (r, c, v) = make_graph(seed=4)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        with obs.capture() as rec:
            with audit.at_level("boundary"), \
                    faults.inject(
                        "dist.compressed_exchange:corrupt_val:count=99"), \
                    pytest.warns(RuntimeWarning, match="degrading pipeline"):
                _, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                         compress="int8")
            rungs = rec.events("ladder.rung")
            ctr = rec.counters()
        assert plan.degraded
        assert any(e["rung"].startswith("serial-schedule") for e in rungs)
        assert ctr["ladder.rungs"] >= 1


# --------------------------------------------------------------------------
# determinism: identical seeded runs -> identical counters (subprocess)
# --------------------------------------------------------------------------

class TestDeterminism:
    def test_counters_deterministic_across_runs(self):
        script = os.path.join(os.path.dirname(__file__), "obs_scenario.py")
        env = dict(os.environ, REPRO_DEVICES="4")
        env.pop("XLA_FLAGS", None)
        outs = []
        for _ in range(2):
            proc = subprocess.run([sys.executable, script],
                                  capture_output=True, text=True, env=env,
                                  timeout=600)
            assert proc.returncode == 0, proc.stderr[-2000:]
            outs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
        assert outs[0] == outs[1]
        assert any(k.startswith("comm.bytes.") for k in outs[0])
