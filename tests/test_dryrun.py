"""Dry-run integration: one small cell must lower+compile on both meshes
(subprocess: the dry-run owns its 512 forced host devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_dryrun(extra, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + extra,
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    return proc.stdout


def test_single_and_multipod_cell(tmp_path):
    out = run_dryrun(["--arch", "mamba2-2.7b", "--shape", "long_500k",
                      "--both-meshes", "--out-dir", str(tmp_path)])
    assert "dry-run OK" in out
    assert "CompiledMemoryStats" in out          # memory_analysis printed
    assert "flops" in out                        # cost_analysis printed
    files = sorted(os.listdir(tmp_path))
    assert any("_sp" in f for f in files) and any("_mp" in f for f in files)
    d = json.load(open(tmp_path / [f for f in files if "_sp" in f][0]))
    assert d["terms_seconds"]["compute"] >= 0
    assert d["dominant"] in ("compute", "memory", "collective")
    assert d["collective"]["counts"]["all-reduce"] >= 0


def test_registry_cell_accounting():
    from repro.configs.registry import valid_cells, cell_valid
    cells = valid_cells()
    assert len(cells) == 31                      # DESIGN.md §6 accounting
    ok, why = cell_valid("hubert-xlarge", "decode_32k")
    assert not ok and "encoder" in why
    ok, why = cell_valid("qwen2-72b", "long_500k")
    assert not ok and "sub-quadratic" in why
    ok, _ = cell_valid("jamba-1.5-large-398b", "long_500k")
    assert ok
