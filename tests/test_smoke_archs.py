"""Per-architecture smoke tests: REDUCED config, one forward + one train
step on CPU, asserting output shapes and finiteness. The FULL configs are
exercised only via the dry-run (launch/dryrun.py, AOT — no allocation).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke
from repro.models import Model, init_params
from repro.models.model import vocab_padded, period_of
from repro.models.config import param_count, active_param_count
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.data import synthetic_batch

SMOKE_SHAPE = dict(seq=32, batch=2)


def build(arch):
    cfg = get_smoke(arch)
    model = Model(cfg)
    params = init_params(cfg, seed=0)
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCHS, ids=str)
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg, model, params = build(arch)
        batch = synthetic_batch(cfg, SMOKE_SHAPE, seed=1)
        logits, aux, _ = jax.jit(
            lambda p, b: model.forward(p, b))(params, batch)
        B, S = SMOKE_SHAPE["batch"], SMOKE_SHAPE["seq"]
        assert logits.shape == (B, S, vocab_padded(cfg))
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert bool(jnp.isfinite(aux))

    def test_train_step_improves_or_finite(self, arch):
        cfg, model, params = build(arch)
        step_fn = jax.jit(make_train_step(
            model, AdamWConfig(lr=1e-3, warmup_steps=1)))
        opt = init_opt_state(params)
        batch = synthetic_batch(cfg, SMOKE_SHAPE, seed=2)
        p1, opt1, m1 = step_fn(params, opt, batch)
        assert bool(jnp.isfinite(m1["loss"])), m1
        assert bool(jnp.isfinite(m1["grad_norm"]))
        assert float(m1["grad_norm"]) > 0
        # params actually moved
        moved = jax.tree.leaves(jax.tree.map(
            lambda a, b: jnp.any(a != b), params, p1))
        assert any(bool(x) for x in moved)
        # loss is sane cross-entropy: <= log(vocab_padded) + slack
        assert float(m1["loss"]) < np.log(vocab_padded(cfg)) + 2.0

    def test_param_count_positive(self, arch):
        cfg = get_smoke(arch)
        n = param_count(cfg)
        na = active_param_count(cfg)
        assert n > 0 and 0 < na <= n


DECODER_ARCHS = [a for a in ARCHS
                 if get_smoke(a).kind in ("decoder", "ssm", "hybrid")
                 and get_smoke(a).frontend is None]


@pytest.mark.parametrize("arch", DECODER_ARCHS, ids=str)
def test_decode_matches_forward(arch):
    """Prefill+decode with caches must agree with teacher-forced forward.

    Run in f32: this checks algorithmic equivalence (chunked-SSD vs
    recurrence, cached vs full attention), not bf16 path divergence.
    """
    cfg, model, params = (lambda c: (c, Model(c), init_params(c, 0)))(
        get_smoke(arch).scaled(dtype="float32"))
    B, S = 2, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    # teacher-forced logits
    logits_tf, _, _ = model.forward(params, dict(tokens=toks), remat=False)
    # prefill first half, decode the rest one token at a time
    half = S // 2
    caches = model.init_cache(B, S)
    from repro.serve import make_prefill, make_serve_step
    prefill = jax.jit(make_prefill(model))
    step = jax.jit(make_serve_step(model))
    lg, caches = prefill(params, caches, toks[:, :half])
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_tf[:, half - 1], np.float32), rtol=2e-2, atol=2e-2)
    for t in range(half, S):
        offset = jnp.full((B,), t, jnp.int32)
        lg, caches = step(params, caches, toks[:, t:t + 1], offset)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(logits_tf[:, t], np.float32), rtol=2e-2, atol=2e-2)


def test_vocab_and_expert_padding():
    cfg = get_smoke("qwen2-moe-a2.7b")
    from repro.models.model import experts_padded
    assert experts_padded(cfg) >= cfg.n_experts
    assert vocab_padded(cfg) % 256 == 0


def test_jamba_period_structure():
    cfg = get_smoke("jamba-1.5-large-398b")
    assert period_of(cfg) == 8
    kinds = cfg.layer_kinds()
    assert kinds[4] == "attn"
    assert kinds.count("attn") == cfg.n_layers // 8
