"""I/O subsystem tests (paper §6): MM roundtrip, label relabeling, binary."""
import numpy as np
import pytest

from repro.io import (read_binary, read_generalized_tuples, read_mm_header,
                      read_mm_parallel, rmat_coo, rmat_edges,
                      write_binary, write_mm_parallel)


@pytest.fixture
def coo(tmp_path):
    rng = np.random.default_rng(0)
    m, n, nnz = 50, 40, 300
    rows = rng.integers(0, m, nnz).astype(np.int64)
    cols = rng.integers(0, n, nnz).astype(np.int64)
    key = rows * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = rng.random(len(rows))
    return (m, n), rows, cols, vals


def canon(rows, cols, vals, n):
    order = np.argsort(rows * n + cols)
    return rows[order], cols[order], vals[order]


class TestMM:
    @pytest.mark.parametrize("nworkers", [1, 2, 4, 7])
    def test_roundtrip(self, tmp_path, coo, nworkers):
        shape, rows, cols, vals = coo
        path = str(tmp_path / "t.mtx")
        write_mm_parallel(path, shape, rows, cols, vals, nwriters=nworkers)
        shape2, r2, c2, v2 = read_mm_parallel(path, nreaders=nworkers)
        assert shape2 == shape
        a = canon(rows, cols, vals, shape[1])
        b = canon(r2, c2, v2, shape[1])
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        np.testing.assert_allclose(a[2], b[2], rtol=1e-9)

    def test_reader_counts_agree(self, tmp_path, coo):
        shape, rows, cols, vals = coo
        path = str(tmp_path / "t.mtx")
        write_mm_parallel(path, shape, rows, cols, vals)
        ref = read_mm_parallel(path, nreaders=1)
        for nr in (2, 3, 8):
            got = read_mm_parallel(path, nreaders=nr)
            assert len(got[1]) == len(ref[1])

    def test_header(self, tmp_path, coo):
        shape, rows, cols, vals = coo
        path = str(tmp_path / "t.mtx")
        write_mm_parallel(path, shape, rows, cols, vals)
        hdr = read_mm_header(path)
        assert (hdr["m"], hdr["n"]) == shape
        assert hdr["nnz"] == len(rows)

    def test_pattern_symmetric(self, tmp_path):
        path = str(tmp_path / "s.mtx")
        with open(path, "w") as f:
            f.write("%%MatrixMarket matrix coordinate pattern symmetric\n")
            f.write("4\t4\t3\n1\t2\n2\t3\n4\t4\n")
        shape, r, c, v = read_mm_parallel(path, nreaders=2)
        dense = np.zeros((4, 4))
        dense[r, c] = v
        assert dense[0, 1] == 1 and dense[1, 0] == 1   # expanded
        assert dense[3, 3] == 1 and dense.sum() == 5


class TestBinary:
    def test_roundtrip(self, tmp_path, coo):
        shape, rows, cols, vals = coo
        path = str(tmp_path / "t.cbb")
        write_binary(path, shape, rows, cols, vals.astype(np.float64))
        shape2, r2, c2, v2 = read_binary(path, nreaders=3)
        assert shape2 == shape
        np.testing.assert_array_equal(rows, r2)
        np.testing.assert_array_equal(cols, c2)
        np.testing.assert_allclose(vals, v2)


class TestLabelFormat:
    def test_relabel_roundtrip(self, tmp_path):
        # arbitrary string labels, protein-ish
        edges = [("ProtA", "ProtB", 0.9), ("ProtB", "ProtC", 0.5),
                 ("ProtC", "ProtA", 0.7), ("seq_XYZ", "ProtA", 0.2)]
        path = str(tmp_path / "g.lbl")
        with open(path, "w") as f:
            for s, d, w in edges:
                f.write(f"{s}\t{d}\t{w}\n")
        shape, rows, cols, vals, labels = read_generalized_tuples(path, 3)
        assert shape[0] == 4 and len(labels) == 4
        # edges survive relabeling
        name = {lb: i for i, lb in enumerate(labels)}
        got = {(rows[i], cols[i], vals[i]) for i in range(len(rows))}
        want = {(name[s], name[d], w) for s, d, w in edges}
        assert got == want

    def test_scattered_integer_labels(self, tmp_path):
        # the paper's "scattered integers in a wide range" case
        path = str(tmp_path / "w.lbl")
        with open(path, "w") as f:
            f.write("1000000000001\t42\n42\t999\n999\t1000000000001\n")
        shape, rows, cols, vals, labels = read_generalized_tuples(path, 2)
        assert shape[0] == 3
        assert sorted(labels) == ["1000000000001", "42", "999"]
        assert len(rows) == 3 and np.all(vals == 1.0)

    def test_ids_consecutive_and_permuted(self, tmp_path):
        path = str(tmp_path / "big.lbl")
        n = 200
        with open(path, "w") as f:
            for i in range(n):
                f.write(f"v{i}\tv{(i + 1) % n}\n")
        shape, rows, cols, vals, labels = read_generalized_tuples(path, 4)
        assert shape[0] == n
        assert sorted(set(rows) | set(cols)) == list(range(n))
        # hash ordering != insertion ordering (load-balance side effect)
        order = [labels.index(f"v{i}") for i in range(20)]
        assert order != sorted(order)


class TestRMAT:
    def test_deterministic(self):
        a = rmat_edges(8, 8, seed=3)
        b = rmat_edges(8, 8, seed=3)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_shape_and_skew(self):
        shape, rows, cols, vals = rmat_coo(10, 16, seed=1)
        n = 1 << 10
        assert shape == (n, n)
        assert rows.max() < n and cols.max() < n
        # power-law-ish: top-1% of rows hold a disproportionate share
        counts = np.bincount(rows, minlength=n)
        top = np.sort(counts)[-n // 100:].sum()
        assert top > 0.05 * len(rows)

    def test_dedup(self):
        shape, rows, cols, vals = rmat_coo(6, 16, seed=2)
        n = 1 << 6
        assert len(np.unique(rows * n + cols)) == len(rows)
