"""Graph-application tests on a 1×1 grid (single device, full pipeline).

The same code paths run distributed (see dist_scenarios.py apps group); the
1×1 grid exercises every shard_map program with axis sizes 1.
"""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.core import DistSpMat, make_grid
from repro.io import rmat_coo


@pytest.fixture(scope="module")
def mesh():
    return make_grid(1, 1)


def make_graph(n=40, density=0.1, seed=0, symmetric=True):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(dense, 0)
    if symmetric:
        dense = np.maximum(dense, dense.T)
    r, c = np.nonzero(dense)
    return dense, (r.astype(np.int64), c.astype(np.int64),
                   dense[r, c].astype(np.float32))


class TestBFS:
    def test_vs_scipy(self, mesh):
        from repro.apps import bfs_levels
        dense, (r, c, v) = make_graph(48, 0.08, seed=1)
        A = DistSpMat.from_global_coo((48, 48), r, c, v, (1, 1), mesh=mesh,
                                      cap=4096)
        got = bfs_levels(A, 0, mesh=mesh)
        ref = csgraph.shortest_path(sp.csr_matrix(dense), unweighted=True,
                                    indices=0)
        ref = np.where(np.isinf(ref), -1, ref).astype(np.int32)
        np.testing.assert_array_equal(got[:48], ref)


class TestPageRank:
    def test_vs_power_iteration(self, mesh):
        from repro.apps import pagerank
        dense, (r, c, v) = make_graph(32, 0.12, seed=2, symmetric=False)
        # our convention: A[dst, src]; dense[i, j] = edge i -> j
        A = DistSpMat.from_global_coo((32, 32), c, r,
                                      np.ones_like(v), (1, 1), mesh=mesh,
                                      cap=4096)
        got = pagerank(A, mesh=mesh, alpha=0.85, max_iters=200)
        # numpy reference
        n = 32
        out_deg = dense.sum(1)
        P = np.zeros((n, n))
        for i in range(n):
            if out_deg[i]:
                P[:, i] = dense[i] / out_deg[i]
        rref = np.full(n, 1 / n)
        for _ in range(200):
            dangling = rref[out_deg == 0].sum()
            rref = 0.85 * (P @ rref + dangling / n) + 0.15 / n
        rref /= rref.sum()
        np.testing.assert_allclose(got, rref, rtol=1e-3, atol=1e-6)


class TestFastSV:
    @pytest.mark.parametrize("seed,density", [(3, 0.03), (4, 0.08)])
    def test_vs_scipy(self, mesh, seed, density):
        from repro.apps import fastsv
        dense, (r, c, v) = make_graph(60, density, seed=seed)
        A = DistSpMat.from_global_coo((60, 60), r, c, v, (1, 1), mesh=mesh,
                                      cap=4096)
        got = fastsv(A, mesh=mesh)
        ncc, ref = csgraph.connected_components(sp.csr_matrix(dense),
                                                directed=False)
        # labels must induce the same partition
        assert len(set(got)) == ncc
        for lbl in set(ref):
            members = np.nonzero(ref == lbl)[0]
            assert len(set(got[members])) == 1

    def test_two_components(self, mesh):
        from repro.apps import fastsv
        n = 24
        dense = np.zeros((n, n), np.float32)
        for i in range(0, 10):
            dense[i, (i + 1) % 11] = dense[(i + 1) % 11, i] = 1  # ring 0..10
        for i in range(12, n - 1):
            dense[i, i + 1] = dense[i + 1, i] = 1                # path 12..23
        r, c = np.nonzero(dense)
        A = DistSpMat.from_global_coo((n, n), r.astype(np.int64),
                                      c.astype(np.int64), dense[r, c],
                                      (1, 1), mesh=mesh, cap=1024)
        got = fastsv(A, mesh=mesh)
        assert got[0] == got[5] and got[12] == got[23]
        assert got[0] != got[12]
        assert got[11] not in (got[0], got[12])  # isolated vertex


class TestTriangles:
    def test_vs_trace(self, mesh):
        from repro.apps import triangle_count
        dense, (r, c, v) = make_graph(36, 0.15, seed=5)
        A = DistSpMat.from_global_coo((36, 36), r, c,
                                      np.ones_like(v), (1, 1), mesh=mesh,
                                      cap=4096)
        got = triangle_count(A, mesh=mesh)
        ref = int(round(np.trace(np.linalg.matrix_power(dense, 3)) / 6))
        assert got == ref

    def test_known(self, mesh):
        # K4 has 4 triangles
        dense = np.ones((4, 4), np.float32) - np.eye(4, dtype=np.float32)
        r, c = np.nonzero(dense)
        A = DistSpMat.from_global_coo((4, 4), r.astype(np.int64),
                                      c.astype(np.int64), dense[r, c],
                                      (1, 1), mesh=mesh, cap=64)
        from repro.apps import triangle_count
        assert triangle_count(A, mesh=mesh) == 4


class TestHipMCL:
    def test_separates_cliques(self, mesh):
        from repro.apps import hipmcl
        # two 6-cliques joined by a single weak edge + self loops
        n = 12
        dense = np.zeros((n, n), np.float32)
        dense[:6, :6] = 1.0
        dense[6:, 6:] = 1.0
        dense[5, 6] = dense[6, 5] = 0.1
        r, c = np.nonzero(dense)
        A = DistSpMat.from_global_coo((n, n), r.astype(np.int64),
                                      c.astype(np.int64), dense[r, c],
                                      (1, 1), mesh=mesh, cap=1024)
        labels = hipmcl(A, mesh=mesh, inflation=2.0, max_iters=12,
                        prod_cap=1 << 14, out_cap=1 << 12)
        assert len(set(labels[:6])) == 1
        assert len(set(labels[6:])) == 1
        assert labels[0] != labels[6]


class TestMatching:
    def test_maximal_on_bipartite(self, mesh):
        from repro.apps import maximal_matching
        rng = np.random.default_rng(7)
        nr = nc = 32
        dense = (rng.random((nr, nc)) < 0.15).astype(np.float32)
        r, c = np.nonzero(dense)
        A = DistSpMat.from_global_coo((nr, nc), r.astype(np.int64),
                                      c.astype(np.int64), dense[r, c],
                                      (1, 1), mesh=mesh, cap=4096)
        mr, mc = maximal_matching(A, mesh=mesh)
        # consistency
        for i in range(nr):
            if mr[i] >= 0:
                assert mc[mr[i]] == i
                assert dense[i, mr[i]] != 0
        # maximality: no edge joins two unmatched vertices
        for i in range(nr):
            if mr[i] < 0:
                for j in np.nonzero(dense[i])[0]:
                    assert mc[j] >= 0, f"edge ({i},{j}) both unmatched"

    def test_perfect_on_diagonal(self, mesh):
        from repro.apps import maximal_matching
        n = 16
        r = np.arange(n, dtype=np.int64)
        A = DistSpMat.from_global_coo((n, n), r, r, np.ones(n, np.float32),
                                      (1, 1), mesh=mesh, cap=64)
        mr, mc = maximal_matching(A, mesh=mesh)
        np.testing.assert_array_equal(mr, np.arange(n))
        np.testing.assert_array_equal(mc, np.arange(n))
