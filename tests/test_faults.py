"""Chaos matrix: every fault site in robust/faults.KNOWN_SITES must be
either detected-and-recovered (result equal to the unfaulted oracle) or
fail loudly (a typed exception naming the problem) — never a silent wrong
answer. CI pins REPRO_FAULT_SEED (the chaos-smoke job) so any failure here
reproduces locally with the same seed.

Also covers the fault registry itself (spec grammar, inject scoping,
determinism), the tiered auditor's invariant checks, checkpoint CRC
fallback, the degradation ladder, and the straggler watchdog.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (ARITHMETIC, DistSpMat, DistSpMat3D, DistSpVec,
                        make_grid, spgemm_3d)
from repro.core.coo import SENTINEL
from repro.core.plan import (spgemm as spgemm_planned,
                             spmspv as spmspv_planned)
from repro.io.binio import read_binary, write_binary
from repro.io.mmio import read_mm_header, read_mm_parallel, write_mm_parallel
from repro.launch.elastic import StepWatchdog
from repro.robust import audit, faults, recover
from repro.robust.faults import InjectedCrash
from repro.robust.recover import CheckpointedLoop
from repro.train.checkpoint import (CheckpointError, restore_flat,
                                    save_checkpoint)


@pytest.fixture(scope="module")
def mesh():
    return make_grid(1, 1)


def make_graph(n=40, density=0.3, seed=0):
    rng = np.random.default_rng(seed)
    dense = np.where(rng.random((n, n)) < density,
                     rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
    r, c = np.nonzero(dense)
    return dense, (r.astype(np.int64), c.astype(np.int64),
                   dense[r, c].astype(np.float32))


# --------------------------------------------------------------------------
# the registry itself
# --------------------------------------------------------------------------

class TestRegistry:
    def test_spec_grammar(self):
        fs = faults._parse_spec(
            "spgemm2d.comm_a:nan:at=2,count=3,seed=7,amount=0.5;loop.crash:crash")
        assert len(fs) == 2
        f = fs[0]
        assert (f.site, f.kind, f.at, f.count, f.seed, f.amount) == \
            ("spgemm2d.comm_a", "nan", 2, 3, 7, 0.5)
        assert (fs[1].site, fs[1].kind) == ("loop.crash", "crash")
        with pytest.raises(ValueError, match="bad fault spec"):
            faults._parse_spec("justasite")

    def test_inject_scoping_and_activation_window(self):
        assert not any(f.site == "loop.crash" for f in faults.active())
        with faults.inject("loop.crash:crash:at=2,count=2"):
            assert faults.fire("loop.crash") is None          # hit 1 < at
            assert faults.fire("loop.crash") is not None      # hit 2
            assert faults.fire("loop.crash") is not None      # hit 3
            assert faults.fire("loop.crash") is None          # window closed
        assert not any(f.site == "loop.crash" for f in faults.active())

    def test_corruption_is_deterministic(self):
        data = bytes(range(256)) * 8
        outs = []
        for _ in range(2):
            with faults.inject("io.mm_body:corrupt_bytes:seed=3"):
                outs.append(faults.corrupt_bytes("io.mm_body", data))
        assert outs[0] == outs[1] and outs[0] != data


# --------------------------------------------------------------------------
# the auditor: invariants + checksums on hand-broken containers
# --------------------------------------------------------------------------

class TestAudit:
    def _mat(self, mesh):
        import dataclasses
        _, (r, c, v) = make_graph(24, 0.3, seed=1)
        A = DistSpMat.from_global_coo((24, 24), r, c, v, (1, 1), mesh=mesh,
                                      cap=512)
        return A, dataclasses

    def test_boundary_catches_structure(self, mesh):
        A, dc = self._mat(mesh)
        with audit.at_level("boundary"):
            audit.audit_obj(A, "t")                      # pristine passes
            bad = dc.replace(A, nnz=jnp.asarray(A.nnz) + A.cap + 1)
            with pytest.raises(audit.AuditError, match="nnz outside"):
                audit.audit_obj(bad, "t")
            col = np.array(A.col)
            col.reshape(-1)[0] = 24 + 5                  # out of tile bounds
            with pytest.raises(audit.AuditError, match="out of bounds"):
                audit.audit_obj(dc.replace(A, col=jnp.asarray(col)), "t")
            row = np.array(A.row)
            row.reshape(-1)[int(np.asarray(A.nnz).reshape(-1)[0]) + 1] = 3
            with pytest.raises(audit.AuditError, match="padding"):
                audit.audit_obj(dc.replace(A, row=jnp.asarray(row)), "t")

    def test_full_catches_nan_and_order(self, mesh):
        A, dc = self._mat(mesh)
        val = np.array(A.val)
        val.reshape(-1)[1] = np.nan
        bad = dc.replace(A, val=jnp.asarray(val))
        with audit.at_level("boundary"):
            audit.audit_obj(bad, "t")                    # boundary: no sweep
        with audit.at_level("full"):
            with pytest.raises(audit.AuditError, match="non-finite"):
                audit.audit_obj(bad, "t")
            # swap whole entries 0 and 1 -> the packed keys now decrease
            row, col = np.array(A.row), np.array(A.col)
            row.reshape(-1)[[0, 1]] = row.reshape(-1)[[1, 0]]
            col.reshape(-1)[[0, 1]] = col.reshape(-1)[[1, 0]]
            with pytest.raises(audit.AuditError, match="order"):
                audit.audit_obj(dc.replace(A, row=jnp.asarray(row),
                                           col=jnp.asarray(col)), "t")

    def test_checksum_sees_value_flips(self, mesh):
        A, dc = self._mat(mesh)
        pre = audit.checksum_obj(A)
        val = np.array(A.val)
        val.reshape(-1)[0] += 1.0
        assert audit.checksum_obj(dc.replace(A, val=jnp.asarray(val))) != pre
        assert audit.checksum_obj(A) == pre              # stable


# --------------------------------------------------------------------------
# comm-boundary corruption: detected by the audit bracket, recovered by the
# planner's pristine-input retry
# --------------------------------------------------------------------------

class TestCommFaults:
    @pytest.fixture(scope="class")
    def ab(self, mesh):
        dense, (r, c, v) = make_graph(40, 0.3, seed=2)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        return dense, A

    @pytest.mark.parametrize("kind", ["nan", "corrupt_val", "corrupt_idx",
                                      "drop", "dup"])
    def test_spgemm2d_comm_a_detect_and_recover(self, mesh, ab, kind):
        dense, A = ab
        with audit.at_level("boundary"), \
                faults.inject(f"spgemm2d.comm_a:{kind}"), \
                pytest.warns(RuntimeWarning, match="failed audit"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 2 and plan.degraded == ()
        np.testing.assert_allclose(C.to_dense()[:40, :40], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_spgemm2d_comm_b_detect_and_recover(self, mesh, ab):
        dense, A = ab
        with audit.at_level("boundary"), \
                faults.inject("spgemm2d.comm_b:drop"), \
                pytest.warns(RuntimeWarning, match="failed audit"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 2
        np.testing.assert_allclose(C.to_dense()[:40, :40], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_spgemm2d_audit_off_misses_corruption(self, mesh, ab):
        """The documented trade: REPRO_AUDIT=off lets wire faults through."""
        dense, A = ab
        with faults.inject("spgemm2d.comm_a:drop"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 1      # nothing detected
        assert not np.allclose(C.to_dense()[:40, :40], dense @ dense,
                               rtol=1e-4, atol=1e-5)

    def test_spmspv_comm_x_detect_and_recover(self, mesh, ab):
        _, A = ab
        idx = np.array([0, 3, 17, 22], np.int64)
        val = np.array([1.0, 2.0, 0.5, 3.0], np.float32)
        x = DistSpVec.from_global(idx, val, 40, (1, 1), cap=64, mesh=mesh)
        y0, _ = spmspv_planned(A, x, ARITHMETIC, mesh=mesh)
        with audit.at_level("boundary"), \
                faults.inject("spmspv.comm_x:corrupt_val"), \
                pytest.warns(RuntimeWarning, match="failed audit"):
            y, plan = spmspv_planned(A, x, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 2
        i0, v0 = y0.to_global()
        i1, v1 = y.to_global()
        assert np.array_equal(i0, i1) and np.array_equal(v0, v1)

    def test_compressed_exchange_detect_and_recover(self, mesh, ab):
        """Corrupting the int8 wire payload trips the audit bracket; the
        pristine-input retry still runs compressed, so the recovered result
        is exact up to the quantization bound."""
        dense, A = ab
        with audit.at_level("boundary"), \
                faults.inject("dist.compressed_exchange:corrupt_val"), \
                pytest.warns(RuntimeWarning, match="failed audit"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                     compress="int8")
        assert plan.attempts == 2 and plan.degraded == ()
        assert plan.compress == "int8"
        np.testing.assert_allclose(C.to_dense()[:40, :40], dense @ dense,
                                   rtol=0.05, atol=0.5)

    def test_persistent_compressed_fault_sheds_schedule(self, mesh, ab):
        """A compressed exchange that fails audit on every attempt walks the
        ladder to the 'serial-schedule' rung: compression (and overlap) are
        abandoned, the fault site is never reached again, and the exact
        uncompressed result comes back — with the shed features recorded."""
        dense, A = ab
        with audit.at_level("boundary"), \
                faults.inject("dist.compressed_exchange:corrupt_val:count=99"), \
                pytest.warns(RuntimeWarning, match="degrading pipeline"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                     compress="int8")
        assert plan.compress is None and plan.overlap is False
        assert any(d.startswith("serial-schedule:") and "compress=int8" in d
                   for d in plan.degraded), plan.degraded
        np.testing.assert_allclose(C.to_dense()[:40, :40], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_spgemm3d_comm_fails_loud(self, mesh):
        """spgemm_3d has no planner retry wrapper — corruption at its wire
        boundary must raise, not produce a wrong C."""
        from repro.core import compat
        dense, (r, c, v) = make_graph(32, 0.2, seed=3)
        # make_grid collapses layers=1 to a 2D mesh; the 3D containers need
        # the 'layer' axis, so build the degenerate (1,1,1) mesh directly
        mesh3 = compat.make_mesh((1, 1, 1), ("layer", "row", "col"),
                                 devices=jax.devices()[:1])
        A3 = DistSpMat3D.from_global_coo((32, 32), r, c, v, (1, 1, 1),
                                         "acol", mesh=mesh3, cap=512)
        B3 = DistSpMat3D.from_global_coo((32, 32), r, c, v, (1, 1, 1),
                                         "brow", mesh=mesh3, cap=512)
        for site in ("spgemm3d.comm_a", "spgemm3d.comm_b"):
            with audit.at_level("boundary"), \
                    faults.inject(f"{site}:corrupt_idx"), \
                    pytest.raises(audit.AuditError, match=site):
                spgemm_3d(A3, B3, ARITHMETIC, mesh=mesh3, prod_cap=8192,
                          out_cap=4096)

    def test_dist_assemble_full_audit_raises(self, mesh):
        _, (r, c, v) = make_graph(30, 0.2, seed=4)
        with audit.at_level("full"), \
                faults.inject("dist.assemble:corrupt_idx"), \
                pytest.raises(audit.AuditError, match="dist.assemble"):
            DistSpMat.from_global_coo((30, 30), r, c, v, (1, 1), mesh=mesh)


# --------------------------------------------------------------------------
# lying ok flags and the degradation ladder
# --------------------------------------------------------------------------

class TestPlannerFaults:
    def test_plan_spgemm_ok_flip_retries(self, mesh):
        dense, (r, c, v) = make_graph(40, 0.3, seed=5)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        with faults.inject("plan.spgemm.ok:flip"):
            C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 2                 # one spurious overflow
        np.testing.assert_allclose(C.to_dense()[:40, :40], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_plan_spmspv_ok_flip_retries(self, mesh):
        _, (r, c, v) = make_graph(40, 0.3, seed=6)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        x = DistSpVec.from_global(np.array([1, 5], np.int64),
                                  np.array([1.0, 1.0], np.float32),
                                  40, (1, 1), cap=64, mesh=mesh)
        y0, _ = spmspv_planned(A, x, ARITHMETIC, mesh=mesh)
        with faults.inject("plan.spmspv.ok:flip"):
            y, plan = spmspv_planned(A, x, ARITHMETIC, mesh=mesh)
        assert plan.attempts == 2
        i0, v0 = y0.to_global()
        i1, v1 = y.to_global()
        assert np.array_equal(i0, i1) and np.array_equal(v0, v1)

    def test_persistent_merge_fault_walks_ladder(self, mesh):
        """merge.kv_ok armed for the whole call: every deferred-merge
        attempt reports overflow, growth hits the ceiling, and the ladder
        degrades to the sort merge — which avoids the implicated kernel and
        produces the exact result."""
        dense, (r, c, v) = make_graph(44, 0.3, seed=7)
        A = DistSpMat.from_global_coo((44, 44), r, c, v, (1, 1), mesh=mesh)
        try:
            with faults.inject("merge.kv_ok:flip"), \
                    pytest.warns(RuntimeWarning, match="degrading pipeline"):
                C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                         merge="deferred", prod_cap=1 << 15)
            assert "sort-merge" in plan.degraded
            assert plan.attempts > 2
            np.testing.assert_allclose(C.to_dense()[:44, :44], dense @ dense,
                                       rtol=1e-4, atol=1e-5)
        finally:
            recover.reset_degradation()
            # the trace-time flip is baked into compiled executables for
            # these shapes — drop them so later tests can't hit a poisoned
            # cache entry
            jax.clear_caches()

    def test_ladder_rung_order_and_exhaustion(self):
        class P:
            degraded = ()
            merge = "deferred"
            attempts = 1
        assert recover.next_rung(P(), None, kind="spgemm") == "sort-merge"
        assert recover.next_rung(P(), object(), kind="spgemm") == "postfilter"
        p = P()
        p.degraded = recover.LADDER               # everything taken
        assert recover.next_rung(p, object(), kind="spgemm") is None
        assert recover._RUNGS["spmspv"] == ("postfilter",
                                            "pure-jax-segreduce")


# --------------------------------------------------------------------------
# checkpoint integrity: CRC detection + latest-step fallback
# --------------------------------------------------------------------------

class TestCheckpointFaults:
    def test_corrupt_leaf_falls_back_to_previous_step(self, tmp_path):
        d = str(tmp_path)
        rng = np.random.default_rng(0)
        good = {"x": rng.standard_normal(64), "y": np.arange(8)}
        save_checkpoint(d, 1, good)
        with faults.inject("checkpoint.leaf:flip"):
            save_checkpoint(d, 2, {"x": good["x"] * 2, "y": good["y"] + 1})
        # latest (step 2) fails CRC -> loud fallback to step 1
        with pytest.warns(RuntimeWarning, match="falling back"):
            state, step = restore_flat(d)
        assert step == 1
        assert np.array_equal(state["x"], good["x"])
        # explicitly-requested corrupt step fails hard
        with pytest.raises(CheckpointError):
            restore_flat(d, step=2)

    def test_truncated_leaf_detected(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 3, {"x": np.arange(1024, dtype=np.float64)})
        with faults.inject("checkpoint.leaf:truncate:amount=0.5"):
            save_checkpoint(d, 4, {"x": np.arange(1024, dtype=np.float64)})
        with pytest.raises(CheckpointError):
            restore_flat(d, step=4)


# --------------------------------------------------------------------------
# I/O hardening: corrupt/truncated/malformed files fail with named errors
# --------------------------------------------------------------------------

class TestIOFaults:
    def _mm(self, tmp_path):
        rng = np.random.default_rng(1)
        r = rng.integers(0, 50, 200).astype(np.int64)
        c = rng.integers(0, 40, 200).astype(np.int64)
        v = rng.random(200)
        path = str(tmp_path / "m.mtx")
        write_mm_parallel(path, (50, 40), r, c, v)
        return path

    def test_mm_body_truncation_detected(self, tmp_path):
        path = self._mm(tmp_path)
        read_mm_parallel(path, nreaders=1)              # pristine reads fine
        with faults.inject("io.mm_body:truncate:amount=0.5"), \
                pytest.raises(ValueError, match="m.mtx"):
            read_mm_parallel(path, nreaders=1)

    def test_mm_malformed_header_named_errors(self, tmp_path):
        p = tmp_path / "bad.mtx"
        p.write_text("%%MatrixMarket matrix coordinate\n1 1 1\n1 1 1.0\n")
        with pytest.raises(ValueError, match="banner"):
            read_mm_header(str(p))
        p.write_text("%%MatrixMarket matrix coordinate real general\n"
                     "10 10\n1 1 1.0\n")
        with pytest.raises(ValueError, match="size line"):
            read_mm_header(str(p))
        p.write_text("not a matrix\n")
        with pytest.raises(ValueError, match="not a MatrixMarket"):
            read_mm_header(str(p))

    def test_mm_entry_count_mismatch_detected(self, tmp_path):
        path = self._mm(tmp_path)
        with open(path) as f:
            lines = f.readlines()
        (tmp_path / "short.mtx").write_text("".join(lines[:-5]))
        with pytest.raises(ValueError, match="promised"):
            read_mm_parallel(str(tmp_path / "short.mtx"), nreaders=1)

    def test_bin_body_corruption_detected(self, tmp_path):
        rng = np.random.default_rng(2)
        r = rng.integers(0, 50, 300).astype(np.int64)
        c = rng.integers(0, 50, 300).astype(np.int64)
        v = rng.random(300)
        path = str(tmp_path / "m.cbin")
        with faults.inject("io.bin_body:truncate:amount=0.25"):
            write_binary(path, (50, 50), r, c, v)
        with pytest.raises(ValueError, match="truncated body"):
            read_binary(path)

    def test_bin_malformed_headers_named_errors(self, tmp_path):
        p = tmp_path / "junk.cbin"
        p.write_bytes(b"\x00" * 48)
        with pytest.raises(ValueError, match="bad magic"):
            read_binary(str(p))
        p.write_bytes(b"\x01\x02")
        with pytest.raises(ValueError, match="truncated header"):
            read_binary(str(p))
        hdr = np.array([0x434242494F31, 1, 4, 4, 1000, 0], np.int64)
        p.write_bytes(hdr.tobytes())                    # header only, no body
        with pytest.raises(ValueError, match="truncated body"):
            read_binary(str(p))
        hdr[5] = 99
        p.write_bytes(hdr.tobytes())
        with pytest.raises(ValueError, match="dtype code"):
            read_binary(str(p))


# --------------------------------------------------------------------------
# crash + straggler in the checkpointed loop
# --------------------------------------------------------------------------

def _body(it, state):
    x = state["x"]
    return {"x": x * np.float64(1.000001) + np.float64(it)}, bool(it >= 9)


class TestCheckpointedLoop:
    def test_crash_resume_bitwise(self, tmp_path):
        x0 = {"x": np.arange(16, dtype=np.float64)}
        baseline = CheckpointedLoop(None).run(dict(x0), _body, 20)
        d = str(tmp_path / "ck")
        with faults.inject("loop.crash:crash:at=4"):
            with pytest.raises(InjectedCrash):
                CheckpointedLoop(d).run(dict(x0), _body, 20)
        resumed = CheckpointedLoop(d).run(dict(x0), _body, 20)
        assert np.array_equal(resumed["x"], baseline["x"])

    def test_completed_run_resumes_to_done(self, tmp_path):
        d = str(tmp_path / "ck")
        x0 = {"x": np.arange(4, dtype=np.float64)}
        done = CheckpointedLoop(d).run(dict(x0), _body, 20)

        def explode(it, state):
            raise AssertionError("body must not re-run after completion")
        again = CheckpointedLoop(d).run(dict(x0), explode, 20)
        assert np.array_equal(again["x"], done["x"])

    def test_straggler_delay_flagged_by_watchdog(self):
        wd = StepWatchdog(grace=3.0, window=8, min_samples=3)
        x0 = {"x": np.zeros(4)}

        def slow_body(it, state):
            import time
            time.sleep(0.01)
            return state, bool(it >= 7)
        with faults.inject("loop.delay:delay:at=6,amount=0.3"), \
                pytest.warns(RuntimeWarning, match="straggling"):
            CheckpointedLoop(None, watchdog=wd).run(dict(x0), slow_body, 20)


class TestAppCrashResume:
    def test_pagerank_crash_resume_bitwise(self, mesh, tmp_path):
        from repro.apps.pagerank import pagerank
        _, (r, c, v) = make_graph(40, 0.15, seed=9)
        A = DistSpMat.from_global_coo((40, 40), r, c,
                                      np.ones_like(v), (1, 1), mesh=mesh)
        baseline = pagerank(A, mesh=mesh, max_iters=12, tol=0.0)
        d = str(tmp_path / "pr")
        with faults.inject("loop.crash:crash:at=5"):
            with pytest.raises(InjectedCrash):
                pagerank(A, mesh=mesh, max_iters=12, tol=0.0,
                         checkpoint_dir=d)
        resumed = pagerank(A, mesh=mesh, max_iters=12, tol=0.0,
                           checkpoint_dir=d)
        assert np.array_equal(baseline, resumed)


class TestTopologyFaults:
    """The topology tier (robust/deadline.py): a straggling exchange trips
    a wall-time deadline, gets seeded-backoff retries, sheds the fancy
    schedule, and only a PERSISTENT straggler escalates to TopologyError —
    the elastic checkpoint/regrid signal. Driven by the
    ``dist.exchange_deadline`` delay site and ``loop.device_loss``."""

    def _mat(self, mesh):
        _, (r, c, v) = make_graph(24, 0.2, seed=13)
        return DistSpMat.from_global_coo((24, 24), r, c, v, (1, 1),
                                         mesh=mesh)

    def test_deadline_trip_backoff_then_schedule_shed(self, mesh):
        from repro.robust import deadline
        A = self._mat(mesh)
        ref, p0 = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert p0.overlap and not p0.degraded
        # budget 50ms, 200ms injected straggle for 4 consecutive exchanges:
        # 3 backoff retries, then the serial-schedule rung sheds the
        # overlapped schedule; the 5th exchange is clean -> exact result
        with deadline.configure(startup_deadline=0.05, backoff_base=0.01), \
             faults.inject("dist.exchange_deadline:delay:amount=0.2,count=4"), \
             pytest.warns(RuntimeWarning, match="backing off"):
            got, p = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert any(d.startswith("serial-schedule") for d in p.degraded)
        assert p.attempts == 5
        np.testing.assert_array_equal(got.to_dense(), ref.to_dense())

    def test_persistent_deadline_escalates_to_topology_error(self, mesh):
        from repro.robust import deadline
        from repro.robust.deadline import TopologyError
        A = self._mat(mesh)
        try:
            with deadline.configure(startup_deadline=0.02,
                                    backoff_base=0.005), \
                 faults.inject(
                     "dist.exchange_deadline:delay:amount=0.1,count=99"), \
                 pytest.warns(RuntimeWarning):
                with pytest.raises(TopologyError) as ei:
                    spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
            # ladder exhausted first (rungs 4/5 flip process globals)
            assert ei.value.site == "spgemm2d.comm_a"
        finally:
            recover.reset_degradation()

    def test_device_loss_without_hook_is_fatal(self):
        from repro.robust.deadline import TopologyError

        def body(it, state):
            return {"x": np.asarray(state["x"]) + 1}, False
        with faults.inject("loop.device_loss:crash:at=2"):
            with pytest.raises(TopologyError):
                CheckpointedLoop().run({"x": np.int64(0)}, body, 6)

    def test_device_loss_with_hook_recovers_exactly(self):
        hooked = []

        def body(it, state):
            return {"x": np.asarray(state["x"]) + 1}, False
        loop = CheckpointedLoop(
            on_topology=lambda s, e: (hooked.append(e.site), s)[1])
        with faults.inject("loop.device_loss:crash:at=2"):
            state = loop.run({"x": np.int64(0)}, body, 6)
        assert int(state["x"]) == 6           # no iteration lost or doubled
        assert hooked == ["loop.device_loss"]


# --------------------------------------------------------------------------
# coverage meta-test: the chaos matrix must exercise EVERY known site
# --------------------------------------------------------------------------

def test_every_known_site_is_exercised():
    src = open(os.path.abspath(__file__)).read()
    missed = [s for s in faults.KNOWN_SITES
              if f'"{s}' not in src and f"'{s}" not in src]
    assert not missed, f"fault sites with no chaos coverage: {missed}"
