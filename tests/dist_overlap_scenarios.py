"""Overlap/schedule/compression scenarios for distributed SpGEMM (§4.8),
executed in a subprocess with REPRO_DEVICES forced host devices (tests must
not pollute the main process's single-device jax).

Usage: python tests/dist_overlap_scenarios.py <scenario> [...]
Prints "PASS <scenario>" per scenario or raises.

The core contract under test: overlap=True (double-buffered stage loops)
and overlap=False (bulk-synchronous, optimization_barrier-pinned) run
identical per-stage math in identical order, so their results are BITWISE
equal — across every schedule × merge × masked/unmasked combination. The
SUMMA-ordered schedules ('alltoall', 'bcast', hybrid tuples) additionally
multiply identical stage operands in identical order, so they are bitwise
equal to each other; 'rotate' visits stages in a device-dependent order and
is only required to match the dense oracle numerically.
"""
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS_EXTRA", ""))

import numpy as np                                            # noqa: E402
import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (ARITHMETIC, DistSpMat, DistSpMat3D, make_grid,  # noqa: E402
                        spgemm_2d, spgemm_2d_batched, spgemm_3d,
                        structural)

Q = 2           # 2x2 grid fits the CI REPRO_DEVICES=8 mesh
M = 96
SCHEDULES = {
    "rotate": dict(schedule="rotate"),
    "alltoall": dict(schedule="alltoall"),
    "bcast": dict(schedule="bcast"),
    "hybrid": dict(schedule=("gather",) * (Q - 1) + ("bcast",)),
}


def rand_coo(rng, m, n, density):
    mask = rng.random((m, n)) < density
    r, c = np.nonzero(mask)
    v = (rng.random(len(r)) + 0.5).astype(np.float32)
    dense = np.zeros((m, n), np.float32)
    dense[r, c] = v
    return dense, (r.astype(np.int64), c.astype(np.int64), v)


def _fixture(seed=0, density=0.08, with_mask=False):
    rng = np.random.default_rng(seed)
    mesh = make_grid(Q, Q)
    da, ea = rand_coo(rng, M, M, density)
    db, eb = rand_coo(rng, M, M, density)
    A = DistSpMat.from_global_coo((M, M), *ea, (Q, Q), mesh=mesh, cap=1024)
    B = DistSpMat.from_global_coo((M, M), *eb, (Q, Q), mesh=mesh, cap=1024)
    mk = dm = None
    if with_mask:
        dm, em = rand_coo(rng, M, M, 0.1)
        Mm = DistSpMat.from_global_coo((M, M), *em, (Q, Q), mesh=mesh,
                                       cap=1024)
        mk = structural(Mm)
    return mesh, A, B, da, db, mk, dm


def _fields(c):
    return [np.asarray(x) for x in (c.row, c.col, c.val, c.nnz)]


def _run(mesh, A, B, *, merge, mask=None, overlap=True, **kw):
    c, ok = spgemm_2d(A, B, ARITHMETIC, mesh=mesh, prod_cap=1 << 13,
                      out_cap=1 << 12, merge=merge, mask=mask,
                      overlap=overlap, **kw)
    assert bool(jnp.all(ok)), "overflow"
    return c


def scenario_overlap_bitwise(sched_name):
    """overlap on == overlap off BITWISE, for every merge and masked/not."""
    mesh, A, B, da, db, mk, dm = _fixture(with_mask=True)
    kw = SCHEDULES[sched_name]
    combos = [(m, None, None) for m in ("sort", "deferred", "incremental")]
    combos.append(("deferred", mk, dm))
    for merge, mask, dmask in combos:
        on = _run(mesh, A, B, merge=merge, mask=mask, overlap=True, **kw)
        off = _run(mesh, A, B, merge=merge, mask=mask, overlap=False, **kw)
        for x, y in zip(_fields(on), _fields(off)):
            np.testing.assert_array_equal(
                x, y, err_msg=f"{sched_name}:{merge}:masked={mask is not None}"
                " overlap on/off disagree bitwise")
        ref = da @ db if dmask is None else (da @ db) * (dmask != 0)
        np.testing.assert_allclose(on.to_dense()[:M, :M], ref,
                                   rtol=1e-4, atol=1e-5)
    print(f"PASS overlap_bitwise:{sched_name}")


def scenario_schedule_equivalence():
    """SUMMA-ordered schedules (alltoall/bcast/hybrid) agree bitwise with
    each other; rotate agrees with the oracle numerically."""
    mesh, A, B, da, db, _, _ = _fixture(seed=3)
    outs = {name: _run(mesh, A, B, merge="deferred", **kw)
            for name, kw in SCHEDULES.items()}
    base = _fields(outs["alltoall"])
    for name in ("bcast", "hybrid"):
        for x, y in zip(base, _fields(outs[name])):
            np.testing.assert_array_equal(
                x, y, err_msg=f"alltoall vs {name} disagree bitwise")
    for name, c in outs.items():
        np.testing.assert_allclose(c.to_dense()[:M, :M], da @ db,
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"{name} vs dense oracle")
    print("PASS schedule_equivalence")


def scenario_overlap_bitwise_3d():
    """3D CA: fused tree all-to-all (overlap) == per-field a2a (serial)."""
    L = 2
    mesh = make_grid(Q, Q, layers=L)
    rng = np.random.default_rng(5)
    da, ea = rand_coo(rng, 80, 80, 0.08)
    db, eb = rand_coo(rng, 80, 80, 0.08)
    A3 = DistSpMat3D.from_global_coo((80, 80), *ea, (L, Q, Q), "acol",
                                     mesh=mesh, cap=256)
    B3 = DistSpMat3D.from_global_coo((80, 80), *eb, (L, Q, Q), "brow",
                                     mesh=mesh, cap=256)
    outs = []
    for overlap in (True, False):
        c3, ok = spgemm_3d(A3, B3, ARITHMETIC, mesh=mesh, prod_cap=8192,
                           out_cap=4096, overlap=overlap)
        assert bool(jnp.all(ok)), "overflow"
        outs.append([np.asarray(x) for x in (c3.row, c3.col, c3.val,
                                             c3.nnz)])
        np.testing.assert_allclose(c3.to_dense()[:80, :80], da @ db,
                                   rtol=1e-4, atol=1e-5)
    for x, y in zip(*outs):
        np.testing.assert_array_equal(x, y,
                                      err_msg="3D overlap on/off disagree")
    print("PASS overlap_bitwise_3d")


def scenario_compressed_exchange():
    """int8-compressed wire payloads: bounded error vs the uncompressed
    result, bitwise-stable under the overlap toggle, on rotate AND hybrid
    schedules."""
    mesh, A, B, da, db, _, _ = _fixture(seed=7)
    exact = _run(mesh, A, B, merge="deferred", schedule="rotate")
    dex = exact.to_dense()[:M, :M]
    # per-entry error bound: each int8 value carries |err| <= scale/2 with
    # scale <= max|val|/127 <= 1.5/127; products of two quantized operands
    # then sum over <= M contraction terms
    vmax = 1.5
    tol = 2 * (vmax / 254) * vmax * (np.count_nonzero(da, axis=0).max() + 1)
    for name in ("rotate", "alltoall", "bcast", "hybrid"):
        on = _run(mesh, A, B, merge="deferred", compress="int8",
                  overlap=True, **SCHEDULES[name])
        off = _run(mesh, A, B, merge="deferred", compress="int8",
                   overlap=False, **SCHEDULES[name])
        for x, y in zip(_fields(on), _fields(off)):
            np.testing.assert_array_equal(
                x, y, err_msg=f"compressed {name} overlap on/off disagree")
        err = np.abs(on.to_dense()[:M, :M] - dex).max()
        assert err <= tol, (name, err, tol)
        assert err > 0, "compression was a silent no-op"
    print("PASS compressed_exchange")


def scenario_compressed_batched_feedback():
    """spgemm_2d_batched with compress='int8': error feedback across
    batches keeps every batch within the single-shot error bound, and the
    union of batches matches the full product."""
    mesh, A, B, da, db, _, _ = _fixture(seed=9)
    outs = spgemm_2d_batched(A, B, ARITHMETIC, mesh=mesh, prod_cap=1 << 13,
                             out_cap=1 << 12, nbatch=2, compress="int8")
    vmax = 1.5
    tol = 2 * (vmax / 254) * vmax * (np.count_nonzero(da, axis=0).max() + 1)
    acc = np.zeros((M, M), np.float32)
    for c, ok in outs:
        assert bool(jnp.all(ok))
        acc = acc + c.to_dense()[:M, :M]
    assert np.abs(acc - da @ db).max() <= tol
    print("PASS compressed_batched_feedback")


def scenario_compress_rejects_bad_semiring():
    """Non-zero additive identity (MIN_PLUS) must be rejected loudly."""
    from repro.core import MIN_PLUS
    mesh, A, B, _, _, _, _ = _fixture(seed=11)
    try:
        spgemm_2d(A, B, MIN_PLUS, mesh=mesh, prod_cap=1 << 13,
                  out_cap=1 << 12, compress="int8")
    except ValueError as e:
        assert "identity" in str(e)
    else:
        raise AssertionError("compress='int8' accepted a +inf identity")
    print("PASS compress_rejects_bad_semiring")


SCENARIOS = {
    "overlap_bitwise_rotate": lambda: scenario_overlap_bitwise("rotate"),
    "overlap_bitwise_alltoall": lambda: scenario_overlap_bitwise("alltoall"),
    "overlap_bitwise_bcast": lambda: scenario_overlap_bitwise("bcast"),
    "overlap_bitwise_hybrid": lambda: scenario_overlap_bitwise("hybrid"),
    "schedule_equivalence": scenario_schedule_equivalence,
    "overlap_bitwise_3d": scenario_overlap_bitwise_3d,
    "compressed_exchange": scenario_compressed_exchange,
    "compressed_batched_feedback": scenario_compressed_batched_feedback,
    "compress_rejects_bad_semiring": scenario_compress_rejects_bad_semiring,
}


if __name__ == "__main__":
    names = sys.argv[1:] or list(SCENARIOS)
    for name in names:
        SCENARIOS[name]()
