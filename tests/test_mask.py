"""Masked-primitive semantics (DESIGN.md §4.7).

The contract under test: masked SpGEMM == unmasked-then-filter, for every
mask kind (structural / complement / mask-value predicate / output-value
predicate), every local algorithm (ESC, dense accumulator), every 2D
variant×merge combination the planner can pick, across tagged and
user-defined semirings, padded and overflowing capacities. Plus the oracle
tests: fused masked tricount == the seed post-filter pipeline on RMAT
inputs, masked SpMSpV == post-hoc spvec_mask. Property tests draw via
hypothesis when installed and degrade to deterministic seeds otherwise
(tests/_hypothesis_stub).
"""
import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import given, settings, st

from repro.core import (ARITHMETIC, BOOLEAN, MIN_PLUS, DistSpMat, DistSpVec,
                        DistVec, make_grid)
from repro.core.coo import COO, SENTINEL, ewise_intersect
from repro.core.local_spgemm import spgemm_dense, spgemm_esc
from repro.core.mask import (LocalMask, MaskSpec, complement_of, local_mask,
                             mask_member, structural, value_mask,
                             vector_mask)
from repro.core.merge import (kv_from_products, kv_to_coo,
                              merge_stage_products, pack_keys)
from repro.core.plan import (plan_local_spgemm, plan_spgemm, plan_spmspv,
                             spgemm as spgemm_planned,
                             spmspv as spmspv_planned)
from repro.core.semiring import Monoid, Semiring
from repro.io import rmat_coo

USER_ADD = Monoid(lambda a, b: a + b + a * b, 0.0, None, "user_probab")
USER_SR = Semiring(USER_ADD, jnp.multiply, "user")

SEMIRINGS = {
    "arithmetic": (ARITHMETIC, 0.0),
    "min_plus": (MIN_PLUS, np.inf),
    "user": (USER_SR, 0.0),
}


def rand_tile(n=24, density=0.3, seed=0, cap=384):
    # FIXED cap across seeds: repeated cases reuse compiled executables
    rng = np.random.default_rng(seed)
    d = np.where(rng.random((n, n)) < density,
                 rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
    return d, COO.from_dense(jnp.asarray(d), cap=cap)


def rand_mask(n=24, density=0.3, seed=100, cap=384):
    rng = np.random.default_rng(seed)
    m = np.where(rng.random((n, n)) < density,
                 rng.random((n, n)).astype(np.float32) + 0.01, 0.0)
    return m, COO.from_dense(jnp.asarray(m), cap=cap)


def semiring_matmul_ref(da, db, sr_name):
    """Dense oracle for the supported semirings."""
    if sr_name == "arithmetic":
        return da @ db, 0.0
    if sr_name == "min_plus":
        a = np.where(da != 0, da, np.inf)
        b = np.where(db != 0, db, np.inf)
        out = np.min(a[:, :, None] + b[None, :, :], axis=1)
        return out, np.inf
    # user: a ⊕ b = a+b+ab over products a_ik*b_kj, identity 0
    n = da.shape[0]
    out = np.zeros((n, n), np.float64)
    for k in range(n):
        p = np.outer(da[:, k], db[k, :])
        out = out + p + out * p
    return out.astype(np.float32), 0.0


class TestProbe:
    def test_membership_matches_dense(self):
        m, mt = rand_mask(seed=3)
        lm = local_mask(mt)
        rng = np.random.default_rng(0)
        r = rng.integers(0, 24, 64).astype(np.int32)
        c = rng.integers(0, 24, 64).astype(np.int32)
        keys = pack_keys(jnp.asarray(r), jnp.asarray(c), (24, 24), "row")
        got = np.asarray(mask_member(keys, lm))
        np.testing.assert_array_equal(got, (m != 0)[r, c])
        # complement flips live candidates, never padding
        lmc = LocalMask(lm.keys, lm.allow, True)
        gotc = np.asarray(mask_member(keys, lmc))
        np.testing.assert_array_equal(gotc, (m == 0)[r, c])

    def test_padding_never_member(self):
        _, mt = rand_mask(seed=4)
        lm = local_mask(mt)
        pad = jnp.full((8,), np.int32(2**31 - 1), jnp.int32)
        keys = pack_keys(pad, pad, (24, 24), "row")
        assert not np.any(np.asarray(mask_member(keys, lm)))
        lmc = LocalMask(lm.keys, lm.allow, True)
        assert not np.any(np.asarray(mask_member(keys, lmc)))

    def test_value_pred_subselects(self):
        m, mt = rand_mask(seed=5)
        lm = local_mask(mt, pred=lambda v: v > 0.5)
        r, c = np.nonzero(m)
        keys = pack_keys(jnp.asarray(r.astype(np.int32)),
                         jnp.asarray(c.astype(np.int32)), (24, 24), "row")
        got = np.asarray(mask_member(keys, lm))
        np.testing.assert_array_equal(got, m[r, c] > 0.5)


class TestLocalMaskedSpGEMM:
    @pytest.mark.parametrize("name", sorted(SEMIRINGS))
    @pytest.mark.parametrize("complement", [False, True])
    def test_masked_equals_postfilter(self, name, complement):
        sr, zero = SEMIRINGS[name]
        for seed in range(3):
            da, A = rand_tile(seed=seed)
            db, B = rand_tile(seed=seed + 30)
            m, Mt = rand_mask(seed=seed + 60)
            lm = local_mask(Mt, complement=complement)
            c, ok = spgemm_esc(A, B, sr, prod_cap=1 << 13, out_cap=1 << 10,
                               mask=lm)
            assert bool(ok)
            ref, _ = semiring_matmul_ref(da, db, name)
            member = (m == 0) if complement else (m != 0)
            want = np.where(member & np.isfinite(ref) & (ref != zero),
                            ref, zero)
            got = np.asarray(c.to_dense(zero))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_dense_path_matches_esc_path(self):
        da, A = rand_tile(seed=9, density=0.4)
        m, Mt = rand_mask(seed=10)
        lm = local_mask(Mt)
        c1, ok1 = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                             out_cap=1 << 10, mask=lm)
        c2, ok2 = spgemm_dense(A, A, ARITHMETIC, out_cap=1 << 10, mask=lm)
        assert bool(ok1) and bool(ok2)
        np.testing.assert_allclose(np.asarray(c1.to_dense()),
                                   np.asarray(c2.to_dense()), rtol=1e-4)

    def test_mask_value_pred(self):
        da, A = rand_tile(seed=11)
        m, Mt = rand_mask(seed=12)
        lm = local_mask(Mt, pred=lambda v: v > 0.5)
        c, ok = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                           out_cap=1 << 10, mask=lm)
        assert bool(ok)
        want = (da @ da) * (m > 0.5)
        np.testing.assert_allclose(np.asarray(c.to_dense()), want, rtol=1e-4,
                                   atol=1e-5)

    def test_output_val_pred(self):
        da, A = rand_tile(seed=13)
        c, ok = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                           out_cap=1 << 10, val_pred=lambda v: v > 2.0)
        assert bool(ok)
        ref = da @ da
        np.testing.assert_allclose(np.asarray(c.to_dense()),
                                   np.where(ref > 2.0, ref, 0.0), rtol=1e-4)

    def test_col_order_caller_probes_mask_correctly(self):
        """Masked kernels running order='col' must probe with the MASK's
        packing order — a mismatched probe silently drops real products."""
        da, A = rand_tile(seed=30)
        m, Mt = rand_mask(seed=31)
        lm = local_mask(Mt)                      # packed row-major
        c, ok = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                           out_cap=1 << 10, order="col", mask=lm)
        assert bool(ok)
        np.testing.assert_allclose(np.asarray(c.to_dense()),
                                   (da @ da) * (m != 0), rtol=1e-4,
                                   atol=1e-5)

    def test_overflowing_out_cap_detected(self):
        """A mask-sized out_cap that is still too small must trip ok, not
        silently truncate."""
        da, A = rand_tile(seed=14, density=0.5)
        m, Mt = rand_mask(seed=15, density=0.9)
        lm = local_mask(Mt)
        _, ok = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13, out_cap=16,
                           mask=lm)
        assert not bool(ok)

    def test_planner_mask_bound_shrinks_out_cap(self):
        _, A = rand_tile(seed=16, density=0.4)
        m, Mt = rand_mask(seed=17, density=0.05)
        p_full = plan_local_spgemm(A, A)
        p_mask = plan_local_spgemm(A, A, mask_nnz=int((m != 0).sum()))
        assert p_mask.out_cap < p_full.out_cap

    @given(st.integers(min_value=0, max_value=10**6))
    @settings(max_examples=10, deadline=None)
    def test_property_masked_equals_postfilter(self, seed):
        """Hypothesis-drawn tiles/masks: fused == unmasked-then-intersect."""
        da, A = rand_tile(seed=seed % 997, density=0.25)
        m, Mt = rand_mask(seed=(seed // 7) % 997, density=0.3)
        lm = local_mask(Mt)
        fused, ok_f = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                                 out_cap=1 << 10, mask=lm)
        full, ok_u = spgemm_esc(A, A, ARITHMETIC, prod_cap=1 << 13,
                                out_cap=1 << 10)
        assert bool(ok_f) and bool(ok_u)
        want = ewise_intersect(full, Mt, lambda x, y: x,
                               out_cap=fused.cap)
        assert int(fused.nnz) == int(want.nnz)
        np.testing.assert_allclose(np.asarray(fused.to_dense()),
                                   np.asarray(want.to_dense()), rtol=1e-4)


class TestKvMaskFilterStage:
    """The merge-engine mask-filter stage (kv pipeline, pre-compaction)."""

    def test_kv_from_products_masked(self):
        da, A = rand_tile(seed=20)
        m, Mt = rand_mask(seed=21)
        from repro.core.local_spgemm import _expand
        r, c, v, n, ok = _expand(A, A, ARITHMETIC, 1 << 13)
        lm = local_mask(Mt)
        k, vv, ng, okk = kv_from_products(r, c, v, n, (24, 24),
                                          ARITHMETIC.add, 1 << 10, mask=lm)
        assert bool(okk)
        got = kv_to_coo(k, vv, ng, (24, 24), ARITHMETIC.add, 1 << 10)
        want = (da @ da) * (m != 0)
        np.testing.assert_allclose(np.asarray(got.to_dense()), want,
                                   rtol=1e-4, atol=1e-5)

    def test_merge_stage_products_masked_small_caps(self):
        """Mask-sized stage/out caps hold exactly the masked result."""
        da, A = rand_tile(seed=22, density=0.35)
        m, Mt = rand_mask(seed=23, density=0.1)
        from repro.core.local_spgemm import _expand
        halves = []
        for lo, hi in ((0, 12), (12, 24)):
            keep = (np.asarray(A.col) >= lo) & (np.asarray(A.col) < hi)
            idx = np.argsort(~keep, kind="stable")
            r = np.asarray(A.row)[idx].copy()
            c = np.asarray(A.col)[idx].copy()
            v = np.asarray(A.val)[idx].copy()
            k = int(keep.sum())
            r[k:], c[k:], v[k:] = SENTINEL, SENTINEL, 0
            halves.append(COO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v),
                              jnp.asarray(k, jnp.int32), A.shape, "row"))
        rows_a = [_expand(halves[s], halves[s].transpose().sort("row"),
                          ARITHMETIC, 1 << 12) for s in range(2)]
        stages = [(o[0], o[1], o[2], jnp.minimum(o[3], 1 << 12))
                  for o in rows_a]
        mask_cap = int((m != 0).sum()) + 8
        lm = local_mask(Mt)
        got, ok = merge_stage_products(stages, (24, 24), ARITHMETIC.add,
                                       mask_cap, mask_cap, mask=lm)
        assert bool(ok)
        ref = sum(np.asarray(h.to_dense()) @ np.asarray(h.to_dense()).T
                  for h in halves)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   ref * (m != 0), rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def mesh():
    return make_grid(1, 1)


def make_graph(n=40, density=0.1, seed=0):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(dense, 0)
    dense = np.maximum(dense, dense.T)
    r, c = np.nonzero(dense)
    return dense, (r.astype(np.int64), c.astype(np.int64),
                   dense[r, c].astype(np.float32))


class TestDistributedMasked:
    def test_structural_matches_postfilter(self, mesh):
        dense, (r, c, v) = make_graph(40, 0.15, seed=1)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh,
                                      cap=1024)
        C, used = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                 mask=structural(A))
        want = (dense @ dense) * (dense != 0)
        np.testing.assert_allclose(C.to_dense()[:40, :40], want, rtol=1e-4,
                                   atol=1e-5)
        # mask-intersected planning: structural out_cap never exceeds the
        # unmasked plan's
        assert plan_spgemm(A, A, mask=structural(A)).out_cap \
            <= plan_spgemm(A, A).out_cap

    def test_complement_matches_postfilter(self, mesh):
        dense, (r, c, v) = make_graph(40, 0.15, seed=2)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh,
                                      cap=1024)
        C, _ = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                              mask=complement_of(A))
        want = (dense @ dense) * (dense == 0)
        np.testing.assert_allclose(C.to_dense()[:40, :40], want, rtol=1e-4,
                                   atol=1e-5)

    def test_complement_pred_mask_keeps_full_ceiling(self, mesh):
        """complement_of(M, pred=...) may admit the WHOLE product (pred can
        reject every stored mask entry) — the planner must not shrink the
        retry ceiling below it."""
        dense, (r, c, v) = make_graph(36, 0.3, seed=6)
        A = DistSpMat.from_global_coo((36, 36), r, c, v, (1, 1), mesh=mesh)
        mk = complement_of(A, pred=lambda val: val > 10.0)  # admits nothing
        C, _ = spgemm_planned(A, A, ARITHMETIC, mesh=mesh, mask=mk)
        np.testing.assert_allclose(C.to_dense()[:36, :36], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_lowball_masked_plan_retries_to_correct(self, mesh):
        from repro.core.plan import SpGEMMPlan
        dense, (r, c, v) = make_graph(36, 0.3, seed=3)
        A = DistSpMat.from_global_coo((36, 36), r, c, v, (1, 1), mesh=mesh)
        honest = plan_spgemm(A, A, mask=structural(A))
        lowball = SpGEMMPlan(64, 64, honest.variant, honest.merge,
                             honest.prod_ceiling, honest.out_ceiling, 0, 0)
        C, used = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                 mask=structural(A), plan=lowball)
        assert used.attempts > 1
        want = (dense @ dense) * (dense != 0)
        np.testing.assert_allclose(C.to_dense()[:36, :36], want, rtol=1e-4,
                                   atol=1e-5)

    def test_masked_spmspv_matches_postfilter(self, mesh):
        from repro.core.matops import spvec_mask
        dense, (r, c, v) = make_graph(48, 0.1, seed=4)
        A = DistSpMat.from_global_coo((48, 48), r, c, v, (1, 1), mesh=mesh,
                                      cap=1024)
        x = DistSpVec.from_global(np.array([0, 3], np.int64),
                                  np.ones(2, np.bool_), 48, (1, 1), cap=256,
                                  layout="col", mesh=mesh)
        lv = np.where(np.arange(48) % 3 == 0, 1, -1).astype(np.int32)
        levels = DistVec.from_global(lv, (1, 1), layout="row", mesh=mesh)
        vm = vector_mask(levels, pred=lambda t: t >= 0, complement=True)
        y, plan = spmspv_planned(A, x, BOOLEAN, mesh=mesh, mask=vm)
        y_full, _ = spmspv_planned(A, x, BOOLEAN, mesh=mesh)
        want = spvec_mask(y_full, levels, lambda xv, t: t < 0)
        np.testing.assert_array_equal(
            y.to_global_dense(zero=False)[:48],
            want.to_global_dense(zero=False)[:48])
        # planner intersects out caps with the admissible-row count
        full_plan = plan_spmspv(A, 2)
        masked_plan = plan_spmspv(A, 2, mask_allowed=int((lv < 0).sum()))
        assert masked_plan.out_cap <= full_plan.out_cap

    def test_maskspec_validation(self, mesh):
        dense, (r, c, v) = make_graph(24, 0.2, seed=5)
        A = DistSpMat.from_global_coo((24, 24), r, c, v, (1, 1), mesh=mesh)
        with pytest.raises(ValueError):
            MaskSpec()                               # empty
        with pytest.raises(ValueError):
            MaskSpec(mat=A, vec=DistVec.from_global(
                np.zeros(24, np.float32), (1, 1)))   # two operands
        with pytest.raises(ValueError):
            vector_mask(DistVec.from_global(np.zeros(24, np.float32),
                                            (1, 1)), pred=None)


class TestTricountOracle:
    """Fused masked tricount == the seed post-filter pipeline (RMAT)."""

    @pytest.mark.parametrize("scale,deg,seed", [(5, 6, 1), (6, 4, 7)])
    def test_fused_matches_postfilter_count(self, mesh, scale, deg, seed):
        from repro.apps import triangle_count
        from repro.core.matops import (mat_apply_local, mat_ewise_local,
                                       mat_select_lower, mat_sum)
        shape, r, c, v = rmat_coo(scale, deg, seed=seed)
        n = shape[0]
        dense = np.zeros((n, n), np.float32)
        dense[r, c] = 1.0
        dense = np.maximum(dense, dense.T)
        np.fill_diagonal(dense, 0)
        rr, cc = np.nonzero(dense)
        A = DistSpMat.from_global_coo((n, n), rr.astype(np.int64),
                                      cc.astype(np.int64), dense[rr, cc],
                                      (1, 1), mesh=mesh)
        got = triangle_count(A, mesh=mesh)

        # the seed pipeline: full L·L, then post-hoc ewise intersection
        ones = lambda t: t.apply(lambda x: jnp.ones_like(x))
        l = mat_select_lower(mat_apply_local(A, ones, mesh=mesh), mesh=mesh)
        b, _ = spgemm_planned(l, l, ARITHMETIC, mesh=mesh)
        masked = mat_ewise_local(
            b, l, lambda t1, t2: ewise_intersect(t1, t2, jnp.multiply,
                                                 out_cap=t1.cap), mesh=mesh)
        want = int(mat_sum(masked))
        assert got == want
        # dense oracle too
        ref = int(round(np.trace(np.linalg.matrix_power(dense, 3)) / 6))
        assert got == ref
