"""Pallas kernel tests: shape/dtype sweeps in interpret mode vs ref.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade: property tests importorskip at run
    from _hypothesis_stub import given, settings, st

from repro.kernels import ref, segreduce
from repro.kernels.bsr_spmm import bsr_spmm, to_blocked_ell
from repro.kernels.flash_attention import flash_attention
from repro.kernels.segreduce import segment_reduce_pallas
from repro.kernels.semiring_matmul import semiring_matmul
from repro.kernels.ssd_chunk import ssd_chunk


class TestSemiringMatmul:
    @pytest.mark.parametrize("kind", ["plus_times", "min_plus", "max_min"])
    @pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                       (128, 256, 128)])
    def test_vs_ref(self, kind, shape):
        M, K, N = shape
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        got = semiring_matmul(a, b, kind=kind, interpret=True)
        want = ref.semiring_matmul(a, b, kind)
        # blockwise K accumulation reassociates the sum vs the oracle
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_or_and(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.random((128, 128)) < 0.2)
        b = jnp.asarray(rng.random((128, 128)) < 0.2)
        got = semiring_matmul(a, b, kind="or_and", interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.semiring_matmul(
                                          a, b, "or_and")))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((128, 128)), dtype)
        b = jnp.asarray(rng.standard_normal((128, 128)), dtype)
        got = semiring_matmul(a, b, kind="plus_times", interpret=True)
        want = jnp.dot(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)

    def test_block_shape_sweep(self):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        want = a @ b
        for bm, bn, bk in [(128, 128, 128), (64, 128, 256), (256, 256, 64)]:
            got = semiring_matmul(a, b, kind="plus_times", bm=bm, bn=bn,
                                  bk=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)


class TestBsrSpmm:
    @pytest.mark.parametrize("density", [0.1, 0.4, 1.0])
    def test_vs_dense(self, density):
        rng = np.random.default_rng(4)
        M, N, n = 256, 384, 128
        bm = bk = 128
        mask = np.kron(rng.random((M // bm, N // bk)) < density,
                       np.ones((bm, bk), bool))
        dense = np.where(mask, rng.standard_normal((M, N)), 0.0) \
            .astype(np.float32)
        cols, vals = to_blocked_ell(dense, bm, bk)
        x = rng.standard_normal((N, n)).astype(np.float32)
        got = bsr_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                       interpret=True)
        np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=1e-4,
                                   atol=1e-4)

    def test_ragged_rows_and_padding(self):
        rng = np.random.default_rng(5)
        bm = bk = 128
        dense = np.zeros((384, 512), np.float32)
        dense[:128, :128] = rng.standard_normal((128, 128))    # row 0: 1 blk
        dense[128:256] = rng.standard_normal((128, 512))       # row 1: all
        # row 2: empty
        cols, vals = to_blocked_ell(dense, bm, bk)
        assert cols[2, 0] == -1
        x = rng.standard_normal((512, 256)).astype(np.float32)
        got = bsr_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                       interpret=True)
        np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=1e-4,
                                   atol=1e-4)

    def test_grouped_matmul_moe_pattern(self):
        """Block-diagonal A == grouped (per-expert) matmul."""
        rng = np.random.default_rng(6)
        E, bm, bk, n = 4, 128, 128, 128
        dense = np.zeros((E * bm, E * bk), np.float32)
        experts = rng.standard_normal((E, bm, bk)).astype(np.float32)
        for e in range(E):
            dense[e * bm:(e + 1) * bm, e * bk:(e + 1) * bk] = experts[e]
        cols, vals = to_blocked_ell(dense, bm, bk)
        x = rng.standard_normal((E * bk, n)).astype(np.float32)
        got = bsr_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                       interpret=True)
        want = np.concatenate(
            [experts[e] @ x[e * bk:(e + 1) * bk] for e in range(E)])
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("S,bq,bkv", [(256, 128, 128), (512, 128, 256)])
    def test_vs_ref(self, causal, S, bq, bkv):
        rng = np.random.default_rng(7)
        B, H, d = 2, 2, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
        got = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                              interpret=True)
        want = ref.flash_attention(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        rng = np.random.default_rng(8)
        B, S, H, d = 1, 256, 2, 128
        mk = lambda: jnp.asarray(rng.standard_normal((B, S, H, d)),
                                 jnp.bfloat16)
        q, k, v = mk(), mk(), mk()
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention(q, k, v, True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestSSDChunk:
    @pytest.mark.parametrize("q,H,P,N", [(64, 4, 32, 32), (128, 2, 64, 64)])
    def test_vs_ref(self, q, H, P, N):
        rng = np.random.default_rng(9)
        G = 3
        xc = jnp.asarray(rng.standard_normal((G, q, H, P)), jnp.float32)
        dtc = jnp.asarray(rng.random((G, q, H)) * 0.1 + 0.01, jnp.float32)
        A = jnp.asarray(-rng.random(H) - 0.5, jnp.float32)
        Bc = jnp.asarray(rng.standard_normal((G, q, N)), jnp.float32)
        Cc = jnp.asarray(rng.standard_normal((G, q, N)), jnp.float32)
        y, st = ssd_chunk(xc, dtc, A, Bc, Cc, interpret=True)
        for g in range(G):
            yr, str_ = ref.ssd_chunk_diag(xc[g], dtc[g], A, Bc[g], Cc[g])
            np.testing.assert_allclose(np.asarray(y[g]), np.asarray(yr),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(st[g]),
                np.asarray(str_).transpose(0, 1, 2), rtol=1e-4, atol=1e-4)

    def test_matches_model_ssd(self):
        """Kernel y_diag+states == models.layers.ssd_chunked single chunk."""
        from repro.models.layers import ssd_chunked
        rng = np.random.default_rng(10)
        B, S, H, P, N = 2, 64, 2, 16, 16
        xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.random((B, S, H)) * 0.1 + 0.01, jnp.float32)
        A = jnp.asarray(-rng.random(H) - 0.5, jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        y_model, final = ssd_chunked(xh, dt, A, Bm, Cm, chunk=S)
        y_k, st_k = ssd_chunk(xh.reshape(B, S, H, P),
                              dt, A, Bm, Cm, interpret=True)
        np.testing.assert_allclose(np.asarray(y_model),
                                   np.asarray(y_k), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(final),
                                   np.asarray(st_k), rtol=1e-4, atol=1e-4)


class TestSegReduce:
    """Pallas segmented semiring reduce (DESIGN.md §4.4) vs XLA oracle."""

    def _oracle(self, v, ids, s, tag):
        import jax.ops as jo
        if tag == "sum":
            return jo.segment_sum(v, ids, s)
        touched = jo.segment_sum(jnp.ones_like(ids), ids, s) > 0
        if tag == "min":
            return jnp.where(touched, jo.segment_min(v, ids, s), jnp.inf)
        return jnp.where(touched, jo.segment_max(v, ids, s), -jnp.inf)

    @pytest.mark.parametrize("tag", ["sum", "min", "max"])
    @pytest.mark.parametrize("n,s", [(1000, 300), (64, 8), (512, 512),
                                     (300, 1)])
    def test_vs_xla(self, tag, n, s):
        rng = np.random.default_rng(0)
        ids = jnp.asarray(np.sort(rng.integers(0, s, n)).astype(np.int32))
        v = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        got = segment_reduce_pallas(v, ids, s, tag, interpret=True)
        want = self._oracle(v, ids, s, tag)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_out_of_range_ids_dropped(self):
        ids = jnp.asarray([0, 1, 5, 7, 9], jnp.int32)
        v = jnp.ones(5, jnp.float32)
        got = segment_reduce_pallas(v, ids, 6, "sum", interpret=True)
        np.testing.assert_allclose(np.asarray(got), [1, 1, 0, 0, 0, 1])

    def test_untouched_segments_hold_identity(self):
        ids = jnp.asarray([2, 2], jnp.int32)
        v = jnp.asarray([4.0, 7.0], jnp.float32)
        got = segment_reduce_pallas(v, ids, 4, "min", interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   [np.inf, np.inf, 4.0, np.inf])

    def test_int_dtype(self):
        got = segment_reduce_pallas(jnp.asarray([3, 4, 5], jnp.int32),
                                    jnp.asarray([0, 0, 2], jnp.int32),
                                    3, "min", interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      [3, 2**31 - 1, 5])

    def test_registered_backend_serves_segment_reduce(self):
        from repro.core import semiring
        rng = np.random.default_rng(1)
        ids = jnp.asarray(np.sort(rng.integers(0, 40, 200)).astype(np.int32))
        v = jnp.asarray(rng.standard_normal(200).astype(np.float32))
        want = semiring.segment_reduce(v, ids, 40, semiring.PLUS,
                                       sorted_ids=True)
        segreduce.register(interpret=True)
        try:
            got = semiring.segment_reduce(v, ids, 40, semiring.PLUS,
                                          sorted_ids=True)
            # vector-valued entries must fall through to the pure-JAX path
            v2 = jnp.stack([v, v], axis=1)
            got2 = semiring.segment_reduce(v2, ids, 40, semiring.PLUS,
                                           sorted_ids=True)
        finally:
            segreduce.unregister()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert got2.shape == (40, 2)

    def test_dedup_through_pallas_backend(self):
        """COO.dedup with the kernel registered == without (end-to-end)."""
        from repro.core.coo import COO
        from repro.core.semiring import PLUS
        rng = np.random.default_rng(2)
        a = COO.from_entries((16, 16), rng.integers(0, 16, 40),
                             rng.integers(0, 16, 40),
                             rng.random(40).astype(np.float32), cap=64)
        want = a.dedup(PLUS)
        segreduce.register(interpret=True)
        try:
            got = a.dedup(PLUS)
        finally:
            segreduce.unregister()
        assert int(got.nnz) == int(want.nnz)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       kind=st.sampled_from(["plus_times", "min_plus", "max_min"]))
def test_property_semiring_matmul(seed, kind):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    got = semiring_matmul(a, b, kind=kind, interpret=True)
    want = ref.semiring_matmul(a, b, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
