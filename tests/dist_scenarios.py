"""Multi-device distributed scenarios, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<N> (tests and benchmarks
must not pollute the main process's single-device jax).

Usage: python tests/dist_scenarios.py <scenario> [seed]
Prints "PASS <scenario>" or raises.
"""
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "16"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS_EXTRA", ""))

import numpy as np                                            # noqa: E402
import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (ARITHMETIC, BOOLEAN, MIN_PLUS, DistSpMat,            # noqa: E402
                        DistSpMat3D, DistSpVec, DistVec, Monoid, make_grid,
                        spgemm_2d, spgemm_3d, spmm_15d, spmm_2d, spmspv,
                        spmv, spmv_iter, transpose_layout, assign, extract)
from repro.core.coo import SENTINEL                           # noqa: E402


def rand_coo(rng, m, n, density):
    mask = rng.random((m, n)) < density
    r, c = np.nonzero(mask)
    v = (rng.random(len(r)) + 0.5).astype(np.float32)
    dense = np.zeros((m, n), np.float32)
    dense[r, c] = v
    return dense, (r.astype(np.int64), c.astype(np.int64), v)


def scenario_spgemm_2d(variant="rotation", merge="deferred"):
    rng = np.random.default_rng(0)
    mesh = make_grid(4, 4)
    M = K = N = 96
    da, ea = rand_coo(rng, M, K, 0.08)
    db, eb = rand_coo(rng, K, N, 0.08)
    A = DistSpMat.from_global_coo((M, K), *ea, (4, 4), mesh=mesh, cap=256)
    B = DistSpMat.from_global_coo((K, N), *eb, (4, 4), mesh=mesh, cap=256)
    C, ok = spgemm_2d(A, B, ARITHMETIC, mesh=mesh, prod_cap=4096,
                      out_cap=2048, variant=variant, merge=merge)
    assert bool(jnp.all(ok)), "overflow"
    got = C.to_dense()[:M, :N]
    np.testing.assert_allclose(got, da @ db, rtol=1e-4, atol=1e-5)
    print(f"PASS spgemm_2d:{variant}:{merge}")


def scenario_spgemm_2d_semiring():
    rng = np.random.default_rng(1)
    mesh = make_grid(4, 4)
    M = 64
    da, ea = rand_coo(rng, M, M, 0.1)
    A = DistSpMat.from_global_coo((M, M), *ea, (4, 4), mesh=mesh, cap=128)
    C, ok = spgemm_2d(A, A, MIN_PLUS, mesh=mesh, prod_cap=4096, out_cap=2048)
    assert bool(jnp.all(ok))
    # min-plus oracle with implicit-zero = +inf semantics
    dd = np.where(da != 0, da, np.inf)
    ref = np.full((M, M), np.inf)
    for k in range(M):
        ref = np.minimum(ref, dd[:, [k]] + dd[[k], :])
    got = C.to_dense(zero=np.inf)[:M, :M]
    mask = np.isfinite(ref)
    np.testing.assert_allclose(got[mask], ref[mask], rtol=1e-4)
    assert np.all(np.isinf(got[~mask]))
    print("PASS spgemm_2d_semiring")


def scenario_spgemm_3d(L=4):
    rng = np.random.default_rng(2)
    q = 2
    mesh = make_grid(q, q, layers=L)
    M = 80
    da, ea = rand_coo(rng, M, M, 0.08)
    db, eb = rand_coo(rng, M, M, 0.08)
    A3 = DistSpMat3D.from_global_coo((M, M), *ea, (L, q, q), "acol",
                                     mesh=mesh, cap=256)
    B3 = DistSpMat3D.from_global_coo((M, M), *eb, (L, q, q), "brow",
                                     mesh=mesh, cap=256)
    C3, ok = spgemm_3d(A3, B3, ARITHMETIC, mesh=mesh, prod_cap=8192,
                       out_cap=4096)
    assert bool(jnp.all(ok)), "overflow"
    got = C3.to_dense()[:M, :M]
    np.testing.assert_allclose(got, da @ db, rtol=1e-4, atol=1e-5)
    print(f"PASS spgemm_3d:L{L}")


def scenario_spgemm_2d_masked(complement=False, merge="deferred"):
    """Masked SUMMA on a real 4x4 grid: fused == dense postfilter oracle."""
    from repro.core import complement_of, structural
    rng = np.random.default_rng(7)
    mesh = make_grid(4, 4)
    M = 96
    da, ea = rand_coo(rng, M, M, 0.08)
    db, eb = rand_coo(rng, M, M, 0.08)
    dm, em = rand_coo(rng, M, M, 0.08)
    A = DistSpMat.from_global_coo((M, M), *ea, (4, 4), mesh=mesh, cap=256)
    B = DistSpMat.from_global_coo((M, M), *eb, (4, 4), mesh=mesh, cap=256)
    Mm = DistSpMat.from_global_coo((M, M), *em, (4, 4), mesh=mesh, cap=256)
    mk = complement_of(Mm) if complement else structural(Mm)
    C, ok = spgemm_2d(A, B, ARITHMETIC, mesh=mesh, prod_cap=4096,
                      out_cap=2048, merge=merge, mask=mk)
    assert bool(jnp.all(ok)), "overflow"
    member = (dm == 0) if complement else (dm != 0)
    np.testing.assert_allclose(C.to_dense()[:M, :M], (da @ db) * member,
                               rtol=1e-4, atol=1e-5)
    print(f"PASS spgemm_2d_masked:complement={complement}:{merge}")


def scenario_spgemm_3d_masked(L=2):
    """Masked 3D CA: csub mask gathered along 'layer', pushed into the
    per-layer 2D multiply before the inter-layer all-to-all."""
    from repro.core import structural
    rng = np.random.default_rng(8)
    q = 2
    mesh = make_grid(q, q, layers=L)
    M = 80
    da, ea = rand_coo(rng, M, M, 0.08)
    db, eb = rand_coo(rng, M, M, 0.08)
    dm, em = rand_coo(rng, M, M, 0.1)
    A3 = DistSpMat3D.from_global_coo((M, M), *ea, (L, q, q), "acol",
                                     mesh=mesh, cap=256)
    B3 = DistSpMat3D.from_global_coo((M, M), *eb, (L, q, q), "brow",
                                     mesh=mesh, cap=256)
    M3 = DistSpMat3D.from_global_coo((M, M), *em, (L, q, q), "csub",
                                     mesh=mesh, cap=256)
    C3, ok = spgemm_3d(A3, B3, ARITHMETIC, mesh=mesh, prod_cap=8192,
                       out_cap=2048, mask=structural(M3))
    assert bool(jnp.all(ok)), "overflow"
    np.testing.assert_allclose(C3.to_dense()[:M, :M], (da @ db) * (dm != 0),
                               rtol=1e-4, atol=1e-5)
    print(f"PASS spgemm_3d_masked:L{L}")


def scenario_spmspv_masked(variant="sort"):
    """Vector-masked SpMSpV on 4x4: admissible rows only, pre-exchange."""
    from repro.core import vector_mask
    rng = np.random.default_rng(9)
    mesh = make_grid(4, 4)
    M = 96
    da, ea = rand_coo(rng, M, M, 0.08)
    A = DistSpMat.from_global_coo((M, M), *ea, (4, 4), mesh=mesh, cap=256)
    f = 7
    idx = np.sort(rng.choice(M, f, replace=False)).astype(np.int64)
    val = (rng.random(f) + 0.5).astype(np.float32)
    x = DistSpVec.from_global(idx, val, M, (4, 4), cap=16, mesh=mesh)
    lv = rng.integers(-1, 2, M).astype(np.int32)
    levels = DistVec.from_global(lv, (4, 4), layout="row", mesh=mesh)
    vm = vector_mask(levels, pred=lambda t: t >= 0, complement=True)
    y, ok = spmspv(A, x, ARITHMETIC, mesh=mesh, variant=variant,
                   prod_cap=1024, out_cap=256, mask=vm)
    assert bool(jnp.all(ok))
    xd = np.zeros(M, np.float32)
    xd[idx] = val
    np.testing.assert_allclose(y.to_global_dense()[:M],
                               (da @ xd) * (lv < 0), rtol=1e-4, atol=1e-5)
    print(f"PASS spmspv_masked:{variant}")


def scenario_spmv(variant="row"):
    rng = np.random.default_rng(3)
    mesh = make_grid(4, 4)
    M, N = 96, 96
    da, ea = rand_coo(rng, M, N, 0.1)
    A = DistSpMat.from_global_coo((M, N), *ea, (4, 4), mesh=mesh, cap=256)
    xg = (rng.random(N) + 0.5).astype(np.float32)
    x = DistVec.from_global(xg, (4, 4), layout="col", mesh=mesh)
    y = spmv(A, x, ARITHMETIC, mesh=mesh, variant=variant)
    np.testing.assert_allclose(y.to_global()[:M], da @ xg, rtol=1e-4)
    # iteration-ready variant returns col layout and same values
    y2 = spmv_iter(A, x, ARITHMETIC, mesh=mesh, variant=variant)
    assert y2.layout == "col"
    np.testing.assert_allclose(y2.to_global()[:M], da @ xg, rtol=1e-4)
    print(f"PASS spmv:{variant}")


def scenario_spmspv(variant="sort", merge="sparse"):
    rng = np.random.default_rng(4)
    mesh = make_grid(4, 4)
    M = 96
    da, ea = rand_coo(rng, M, M, 0.08)
    A = DistSpMat.from_global_coo((M, M), *ea, (4, 4), mesh=mesh, cap=256)
    f = 7
    idx = np.sort(rng.choice(M, f, replace=False)).astype(np.int64)
    val = (rng.random(f) + 0.5).astype(np.float32)
    x = DistSpVec.from_global(idx, val, M, (4, 4), cap=16, mesh=mesh)
    y, ok = spmspv(A, x, ARITHMETIC, mesh=mesh, variant=variant,
                   merge=merge, prod_cap=1024, out_cap=256)
    assert bool(jnp.all(ok))
    xd = np.zeros(M, np.float32)
    xd[idx] = val
    np.testing.assert_allclose(y.to_global_dense()[:M], da @ xd, rtol=1e-4,
                               atol=1e-5)
    print(f"PASS spmspv:{variant}:{merge}")


def scenario_spmm(kind="15d"):
    rng = np.random.default_rng(5)
    mesh = make_grid(4, 4)
    M, N, k = 96, 96, 8
    da, ea = rand_coo(rng, M, N, 0.1)
    A = DistSpMat.from_global_coo((M, N), *ea, (4, 4), mesh=mesh, cap=256)
    Xg = (rng.random((N, k)) + 0.5).astype(np.float32)
    if kind == "15d":
        nb_pad = A.nb * 4 - N
        X = DistVec.from_global(np.pad(Xg, ((0, 0),) if nb_pad == 0 else
                                       ((0, nb_pad), (0, 0))),
                                (4, 4), layout="col", mesh=mesh)
        Y = spmm_15d(A, X, ARITHMETIC, mesh=mesh)
        got = Y.to_global()[:M]
    else:
        n_pad = A.nb * 4
        Xp = np.zeros((n_pad, k), np.float32)
        Xp[:N] = Xg
        xs = jax.device_put(Xp, jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec("col", "row")))
        Y = spmm_2d(A, xs, ARITHMETIC, mesh=mesh)
        got = np.asarray(Y)[:M]
    np.testing.assert_allclose(got, da @ Xg, rtol=1e-4, atol=1e-5)
    print(f"PASS spmm:{kind}")


def scenario_assign(skew=False):
    rng = np.random.default_rng(6)
    mesh = make_grid(4, 4)
    N = 96
    xg = rng.random(N).astype(np.float32)
    v = DistVec.from_global(xg, (4, 4), layout="col", mesh=mesh)
    # each device updates 3 random GLOBAL slots
    cap = 4
    gidx = np.full((4, 4, cap), SENTINEL, np.int32)
    gval = np.zeros((4, 4, cap), np.float32)
    ref = xg.copy()
    all_targets = rng.permutation(N)[:16 * 3].reshape(4, 4, 3)
    for i in range(4):
        for j in range(4):
            t = all_targets[i, j]
            gidx[i, j, :3] = t
            gval[i, j, :3] = (i * 4 + j) + np.arange(3) + 100.0
            ref[t] = gval[i, j, :3]
    v2, ok = assign(v, jnp.asarray(gidx), jnp.asarray(gval), mesh=mesh,
                    skew_aware=skew)
    assert bool(jnp.all(ok))
    np.testing.assert_allclose(v2.to_global()[:N], ref, rtol=1e-6)
    print(f"PASS assign:skew={skew}")


def scenario_extract():
    rng = np.random.default_rng(7)
    mesh = make_grid(4, 4)
    N = 96
    xg = rng.random(N).astype(np.float32)
    v = DistVec.from_global(xg, (4, 4), layout="col", mesh=mesh)
    cap = 6
    gidx = np.full((4, 4, cap), SENTINEL, np.int32)
    want = np.zeros((4, 4, cap), np.float32)
    for i in range(4):
        for j in range(4):
            t = rng.choice(N, 4, replace=False)
            gidx[i, j, :4] = t
            want[i, j, :4] = xg[t]
    vals, ok = extract(v, jnp.asarray(gidx), mesh=mesh)
    assert bool(jnp.all(ok))
    got = np.asarray(vals)
    mask = gidx != SENTINEL
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-6)
    print("PASS extract")


def scenario_transpose_layout():
    rng = np.random.default_rng(8)
    mesh = make_grid(4, 4)
    N = 64
    xg = rng.random(N).astype(np.float32)
    v = DistVec.from_global(xg, (4, 4), layout="row", mesh=mesh)
    v2 = transpose_layout(v, mesh=mesh)
    assert v2.layout == "col"
    np.testing.assert_allclose(v2.to_global(), xg)
    print("PASS transpose_layout")


def scenario_apps_distributed():
    """Graph apps end-to-end on a REAL 4x4 grid (not just 1x1)."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    from repro.apps import bfs_levels, fastsv
    rng = np.random.default_rng(11)
    n = 64
    dense = (rng.random((n, n)) < 0.06).astype(np.float32)
    np.fill_diagonal(dense, 0)
    dense = np.maximum(dense, dense.T)
    r, c = np.nonzero(dense)
    mesh = make_grid(4, 4)
    A = DistSpMat.from_global_coo((n, n), r.astype(np.int64),
                                  c.astype(np.int64), dense[r, c], (4, 4),
                                  mesh=mesh, cap=512)
    lv = bfs_levels(A, 0, mesh=mesh, prod_cap=1 << 14, out_cap=1 << 10)
    ref = csgraph.shortest_path(sp.csr_matrix(dense), unweighted=True,
                                indices=0)
    ref = np.where(np.isinf(ref), -1, ref).astype(np.int32)
    np.testing.assert_array_equal(lv[:n], ref)
    labels = fastsv(A, mesh=mesh)
    ncc, refcc = csgraph.connected_components(sp.csr_matrix(dense),
                                              directed=False)
    assert len(set(labels)) == ncc
    for lbl in set(refcc):
        members = np.nonzero(refcc == lbl)[0]
        assert len(set(labels[members])) == 1
    print("PASS apps_distributed")


SCENARIOS = {
    "spgemm_2d": lambda: scenario_spgemm_2d(),
    "spgemm_2d_allgather": lambda: scenario_spgemm_2d("allgather"),
    "spgemm_2d_incremental": lambda: scenario_spgemm_2d("rotation",
                                                        "incremental"),
    "spgemm_2d_semiring": scenario_spgemm_2d_semiring,
    "spgemm_3d": lambda: scenario_spgemm_3d(4),
    "spgemm_3d_L2": lambda: scenario_spgemm_3d(2),
    "spgemm_2d_masked": lambda: scenario_spgemm_2d_masked(False),
    "spgemm_2d_masked_complement": lambda: scenario_spgemm_2d_masked(True),
    "spgemm_2d_masked_sort": lambda: scenario_spgemm_2d_masked(
        False, "sort"),
    "spgemm_3d_masked": lambda: scenario_spgemm_3d_masked(2),
    "spmspv_masked": lambda: scenario_spmspv_masked("sort"),
    "spmspv_masked_spa": lambda: scenario_spmspv_masked("spa"),
    "spmv_row": lambda: scenario_spmv("row"),
    "spmv_col": lambda: scenario_spmv("col"),
    "spmspv_sort": lambda: scenario_spmspv("sort", "sparse"),
    "spmspv_spa_dense": lambda: scenario_spmspv("spa", "dense"),
    "spmspv_bucket": lambda: scenario_spmspv("bucket", "sparse"),
    "spmm_15d": lambda: scenario_spmm("15d"),
    "spmm_2d": lambda: scenario_spmm("2d"),
    "assign": lambda: scenario_assign(False),
    "assign_skew": lambda: scenario_assign(True),
    "extract": scenario_extract,
    "transpose_layout": scenario_transpose_layout,
    "apps_distributed": scenario_apps_distributed,
}


if __name__ == "__main__":
    names = sys.argv[1:] or list(SCENARIOS)
    for name in names:
        SCENARIOS[name]()
