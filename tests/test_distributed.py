"""Distributed-op integration tests.

Each test runs tests/dist_scenarios.py in a subprocess with 16 forced host
devices (the main pytest process keeps its single device — required for the
smoke tests and benchmarks).
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SCRIPT = os.path.join(HERE, "dist_scenarios.py")

GROUPS = {
    "spgemm2d": ["spgemm_2d", "spgemm_2d_allgather", "spgemm_2d_incremental",
                 "spgemm_2d_semiring"],
    "spgemm3d": ["spgemm_3d", "spgemm_3d_L2"],
    "masked": ["spgemm_2d_masked", "spgemm_2d_masked_complement",
               "spgemm_2d_masked_sort", "spgemm_3d_masked",
               "spmspv_masked", "spmspv_masked_spa"],
    "spmv": ["spmv_row", "spmv_col", "transpose_layout"],
    "spmspv": ["spmspv_sort", "spmspv_spa_dense", "spmspv_bucket"],
    "spmm": ["spmm_15d", "spmm_2d"],
    "assign": ["assign", "assign_skew", "extract"],
    "apps": ["apps_distributed"],
}


def run_scenarios(names):
    env = dict(os.environ, REPRO_DEVICES="16")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, SCRIPT] + names,
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, \
        f"scenarios {names} failed:\n{proc.stdout}\n{proc.stderr}"
    for n in names:
        assert "PASS" in proc.stdout


@pytest.mark.parametrize("group", sorted(GROUPS), ids=str)
def test_distributed_group(group):
    run_scenarios(GROUPS[group])
