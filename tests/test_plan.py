"""Capacity planner + order-invariant + batched SpGEMM tests (1×1 grid).

Covers the three contracts of the planner refactor:
  - apps need no capacity arguments; overflowing first attempts retry with
    grown caps instead of returning truncated results;
  - tiles flowing through assembly / spgemm / matops carry ``order='row'``
    end-to-end (checked against the actual device arrays, not just the tag);
  - ``spgemm_2d_batched`` column slabs concatenate to the unbatched result.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ARITHMETIC, DistSpMat, make_grid
from repro.core.coo import SENTINEL
from repro.core.matops import (mat_apply_local, mat_ewise_local,
                               mat_select_lower, mat_transpose)
from repro.core.plan import (SpGEMMPlan, plan_local_spgemm, plan_spgemm,
                             plan_spmspv, spgemm as spgemm_planned,
                             spmspv_variant_for_density, spmv_variant)
from repro.core.spgemm import _restrict_cols, spgemm_2d, spgemm_2d_batched
from repro.io import rmat_coo


@pytest.fixture(scope="module")
def mesh():
    return make_grid(1, 1)


def make_graph(n=40, density=0.1, seed=0):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(dense, 0)
    dense = np.maximum(dense, dense.T)
    r, c = np.nonzero(dense)
    return dense, (r.astype(np.int64), c.astype(np.int64),
                   dense[r, c].astype(np.float32))


def assert_row_sorted(m: DistSpMat):
    """Tag says 'row' AND the device arrays actually are row-major sorted."""
    assert m.order == "row", f"order tag is {m.order!r}"
    R = np.asarray(m.row).reshape(m.pr * m.pc, m.cap)
    C = np.asarray(m.col).reshape(m.pr * m.pc, m.cap)
    Nz = np.asarray(m.nnz).reshape(-1)
    for t in range(R.shape[0]):
        k = int(Nz[t])
        key = R[t, :k].astype(np.int64) * (m.nb + 1) + C[t, :k]
        assert np.all(np.diff(key) >= 0), f"tile {t} not row-major"
        assert np.all(R[t, k:] == SENTINEL), f"tile {t} padding not canonical"


class TestOrderInvariant:
    def test_assembly_and_ops_preserve_row_order(self, mesh):
        dense, (r, c, v) = make_graph(40, 0.15, seed=3)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh,
                                      cap=1024)
        assert_row_sorted(A)
        # apply (value-only) and prune (stable compaction) keep the order
        A2 = mat_apply_local(A, lambda t: t.apply(lambda x: x * 2), mesh=mesh)
        assert_row_sorted(A2)
        A3 = mat_apply_local(A, lambda t: t.prune(lambda x: x > 0.5),
                             mesh=mesh)
        assert_row_sorted(A3)
        L = mat_select_lower(A, mesh=mesh)
        assert_row_sorted(L)
        # column restriction compacts stably
        assert_row_sorted(_restrict_cols(A, 0, 16))
        # transpose flips the sort direction
        assert mat_transpose(A, mesh=mesh).order == "col"

    def test_spgemm_output_row_sorted(self, mesh):
        dense, (r, c, v) = make_graph(36, 0.2, seed=4)
        A = DistSpMat.from_global_coo((36, 36), r, c, v, (1, 1), mesh=mesh,
                                      cap=1024)
        C, plan = spgemm_planned(A, A, ARITHMETIC, mesh=mesh)
        assert_row_sorted(C)
        np.testing.assert_allclose(C.to_dense()[:36, :36], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_ewise_local_row_sorted(self, mesh):
        from repro.core.coo import ewise_intersect, ewise_union
        from repro.core.semiring import PLUS
        dense, (r, c, v) = make_graph(30, 0.2, seed=5)
        A = DistSpMat.from_global_coo((30, 30), r, c, v, (1, 1), mesh=mesh,
                                      cap=512)
        U = mat_ewise_local(A, A, lambda t1, t2: ewise_union(
            t1, t2, PLUS, cap=t1.cap), mesh=mesh)
        assert_row_sorted(U)
        X = mat_ewise_local(A, A, lambda t1, t2: ewise_intersect(
            t1, t2, jnp.multiply, out_cap=t1.cap), mesh=mesh)
        assert_row_sorted(X)


class TestPlanner:
    def test_caps_scale_with_problem(self, mesh):
        _, (r, c, v) = make_graph(40, 0.05, seed=0)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        _, (r2, c2, v2) = make_graph(40, 0.5, seed=0)
        B = DistSpMat.from_global_coo((40, 40), r2, c2, v2, (1, 1), mesh=mesh)
        pa, pb = plan_spgemm(A, A), plan_spgemm(B, B)
        assert pb.prod_cap > pa.prod_cap       # denser input → bigger caps

    def test_retry_grows_to_correct_result(self, mesh):
        dense, (r, c, v) = make_graph(32, 0.3, seed=1)
        A = DistSpMat.from_global_coo((32, 32), r, c, v, (1, 1), mesh=mesh)
        honest = plan_spgemm(A, A)
        lowball = SpGEMMPlan(64, 64, honest.variant, honest.merge,
                             honest.prod_ceiling, honest.out_ceiling, 0, 0)
        C, used = spgemm_planned(A, A, ARITHMETIC, mesh=mesh, plan=lowball)
        assert used.attempts > 1               # first attempt overflowed
        np.testing.assert_allclose(C.to_dense()[:32, :32], dense @ dense,
                                   rtol=1e-4, atol=1e-5)

    def test_output_overflow_detected_not_truncated(self, mesh):
        """nnz(C) > out_cap must trip ok (pre-clamp check) and retry to the
        full result — with_cap's nnz clamp must not mask the overflow."""
        n = 64
        dense = np.zeros((n, n), np.float32)
        dense[0, :] = 1.0
        dense[:, 0] = 1.0                       # C = A@A is fully dense
        r, c = np.nonzero(dense)
        A = DistSpMat.from_global_coo((n, n), r.astype(np.int64),
                                      c.astype(np.int64), dense[r, c],
                                      (1, 1), mesh=mesh)
        C, used = spgemm_planned(A, A, ARITHMETIC, mesh=mesh,
                                 prod_cap=1 << 16)
        assert used.attempts > 1                # estimator undershot, retried
        np.testing.assert_allclose(C.to_dense()[:n, :n], dense @ dense,
                                   rtol=1e-5)

    def test_explicit_caps_override(self, mesh):
        _, (r, c, v) = make_graph(40, 0.1, seed=2)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        p = plan_spgemm(A, A, prod_cap=1 << 15, out_cap=1 << 12)
        assert p.prod_cap >= 1 << 15 and p.out_cap >= 1 << 12

    def test_rules_of_thumb(self, mesh):
        _, (r, c, v) = make_graph(40, 0.1, seed=2)
        A = DistSpMat.from_global_coo((40, 40), r, c, v, (1, 1), mesh=mesh)
        # tiny problems take the legacy single-sort merge regardless of
        # budget (q·prod_cap below the merge-engine crossover, §4.4)
        assert plan_spgemm(A, A).merge == "sort"
        # above the crossover, a tiny memory budget flips both
        # memory-saving choices...
        p = plan_spgemm(A, A, mem_budget=8, prod_cap=1 << 15)
        assert p.variant == "rotation" and p.merge == "incremental"
        # ...and an ample one picks the deferred merge tree
        p = plan_spgemm(A, A, mem_budget=1 << 30, prod_cap=1 << 15)
        assert p.variant == "allgather" and p.merge == "deferred"
        # Fig-3 density thresholds
        assert spmspv_variant_for_density(0.001) == "sort"
        assert spmspv_variant_for_density(0.05) == "bucket"
        assert spmspv_variant_for_density(0.5) == "spa"
        assert plan_spmspv(A, 40).use_spmv          # dense frontier
        assert not plan_spmspv(A, 1).use_spmv
        # dense-merge rule: only when the frontier is dense AND the add
        # monoid reduces natively (psum_scatter needs 'sum')
        assert plan_spmspv(A, 40, add_tag="sum").merge == "dense"
        assert plan_spmspv(A, 1, add_tag="sum").merge == "sparse"
        assert plan_spmspv(A, 40, add_tag="max").merge == "sparse"
        # bucketed sparse merge splits out_cap across pc destinations: the
        # ceiling must carry the ×pc headroom or skewed outputs can never
        # satisfy the per-bucket bound
        p40 = plan_spmspv(A, 40)
        assert p40.out_ceiling >= A.grid[1] * min(
            int(np.asarray(A.nnz).max()), A.mb)
        assert spmv_variant(A) == "row"
        assert spmv_variant(mat_transpose(A, mesh=mesh)) == "col"

    def test_local_plan_exact_flops_never_overflow(self):
        from repro.core.coo import COO
        from repro.core.local_spgemm import spgemm_esc
        rng = np.random.default_rng(7)
        d = np.where(rng.random((24, 24)) < 0.3,
                     rng.random((24, 24)).astype(np.float32) + 0.5, 0.0)
        A = COO.from_dense(jnp.asarray(d), cap=int((d != 0).sum()) + 8)
        p = plan_local_spgemm(A, A)
        c, ok = spgemm_esc(A, A, ARITHMETIC, prod_cap=p.prod_cap,
                           out_cap=p.out_cap)
        assert bool(ok)
        np.testing.assert_allclose(np.asarray(c.to_dense()), d @ d, rtol=1e-4)

    def test_app_beyond_old_default_caps(self, mesh):
        """Old hard-coded prod_cap=1<<16 would overflow here; the planner
        must size (or grow) past it without any caps in the call."""
        from repro.apps import triangle_count
        dense, (r, c, v) = make_graph(96, 0.45, seed=6)
        A = DistSpMat.from_global_coo((96, 96), r, c, np.ones_like(v),
                                      (1, 1), mesh=mesh)
        got = triangle_count(A, mesh=mesh)
        ref = int(round(np.trace(np.linalg.matrix_power(dense, 3)) / 6))
        assert got == ref


class TestBatchedSpGEMM:
    def test_restrict_cols_partitions(self, mesh):
        _, (r, c, v) = make_graph(32, 0.2, seed=8)
        B = DistSpMat.from_global_coo((32, 32), r, c, v, (1, 1), mesh=mesh)
        whole = B.to_dense()
        lo_half = _restrict_cols(B, 0, 16).to_dense()
        hi_half = _restrict_cols(B, 16, 16).to_dense()
        np.testing.assert_allclose(lo_half + hi_half, whole)
        assert np.all(lo_half[:, 16:] == 0) and np.all(hi_half[:, :16] == 0)

    def test_batched_concatenates_to_unbatched(self, mesh):
        shape, r, c, v = rmat_coo(5, 4, seed=3)
        A = DistSpMat.from_global_coo(shape, r, c, v, (1, 1), mesh=mesh)
        plan = plan_spgemm(A, A)
        full, ok = spgemm_2d(A, A, ARITHMETIC, mesh=mesh,
                             prod_cap=plan.prod_cap, out_cap=plan.out_cap)
        assert bool(jnp.all(ok))
        for nbatch in (2, 4):
            outs = spgemm_2d_batched(A, A, ARITHMETIC, mesh=mesh,
                                     prod_cap=plan.prod_cap,
                                     out_cap=plan.out_cap, nbatch=nbatch)
            acc = np.zeros_like(full.to_dense())
            for cb, okb in outs:
                assert bool(jnp.all(okb))
                acc += cb.to_dense()
            np.testing.assert_allclose(acc, full.to_dense(), rtol=1e-5,
                                       atol=1e-6)
