"""Elastic-restart scenario (subprocess): save sharded on mesh A, restore
sharded on mesh B with different shape — values must round-trip exactly.
"""
import os
import sys

N_DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"

import numpy as np                                            # noqa: E402
import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.train import save_checkpoint                      # noqa: E402
from repro.launch.elastic import reshard_restore             # noqa: E402


def main(tmp):
    ckpt = os.path.join(tmp, "ck")
    rng = np.random.default_rng(0)
    tree = {"w1": rng.standard_normal((16, 32)).astype(np.float32),
            "w2": rng.standard_normal((64,)).astype(np.float32)}
    from repro.core import compat
    mesh_a = compat.make_mesh((2, 4), ("data", "model"),
                              devices=jax.devices()[:8])
    specs = {"w1": P("data", "model"), "w2": P("data")}
    sharded = {k: jax.device_put(v, NamedSharding(mesh_a, specs[k]))
               for k, v in tree.items()}
    save_checkpoint(ckpt, 42, sharded)

    # "cluster changed": new mesh with a different shape
    mesh_b = compat.make_mesh((4, 2), ("data", "model"),
                              devices=jax.devices()[:8])
    like = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in tree.items()}
    restored, step = reshard_restore(ckpt, like, mesh=mesh_b, specs=specs)
    assert step == 42
    for k in tree:
        got = np.asarray(restored[k])
        np.testing.assert_array_equal(got, tree[k])
        sh = restored[k].sharding
        assert sh.mesh.shape["data"] == 4      # actually on the new mesh
    print("PASS elastic")


if __name__ == "__main__":
    main(sys.argv[1])
