"""Unit + property tests for local sparse primitives vs dense oracles."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade: property tests importorskip at run
    from _hypothesis_stub import given, settings, st

from repro.core import semiring as S
from repro.core.coo import COO, SENTINEL, ewise_intersect, ewise_union
from repro.core.local_spgemm import (compression_ratio, spgemm_auto,
                                     spgemm_dense, spgemm_esc, spgemm_flops)
from repro.core.spmv_local import (spmspv_bucket, spmspv_sort, spmspv_spa,
                                   spmspv_auto, spmv_col, spmv_row,
                                   spvec_from_dense, spvec_to_dense)


def rand_sparse(rng, m, n, density=0.2, cap=None, zero=0.0, ints=False):
    dense = np.zeros((m, n), np.int32 if ints else np.float32)
    mask = rng.random((m, n)) < density
    if ints:
        dense[mask] = rng.integers(1, 9, mask.sum())
    else:
        dense[mask] = rng.random(mask.sum()).astype(np.float32) + 0.5
    cap = cap or max(int(mask.sum()) + 8, 16)
    coo = COO.from_dense(jnp.asarray(dense), cap=cap, zero=0)
    return dense, coo


def dense_semiring_mm(a, b, sr):
    """numpy oracle for C = A ⊕.⊗ B with implicit-zero semantics."""
    m, k = a.shape
    k2, n = b.shape
    out = np.full((m, n), sr.add.identity, np.float64)
    an = a != 0 if sr.add.identity != 0 else None
    for i in range(m):
        for j in range(n):
            acc = sr.add.identity
            for t in range(k):
                if a[i, t] != 0 and b[t, j] != 0:
                    p = np.asarray(sr.mul(jnp.float32(a[i, t]),
                                          jnp.float32(b[t, j])))
                    acc = np.asarray(sr.add.op(jnp.float32(acc),
                                               jnp.float32(p)))
            out[i, j] = acc
    return out


class TestCOO:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        dense, coo = rand_sparse(rng, 13, 17, 0.3)
        np.testing.assert_allclose(np.asarray(coo.to_dense()), dense)

    def test_sort_orders(self):
        rng = np.random.default_rng(1)
        dense, coo = rand_sparse(rng, 11, 9, 0.4)
        for order in ("row", "col"):
            s = coo.sort(order)
            np.testing.assert_allclose(np.asarray(s.to_dense()), dense)
            k1 = np.asarray(s.row if order == "row" else s.col)
            nnz = int(s.nnz)
            assert np.all(np.diff(k1[:nnz]) >= 0)

    def test_dedup_sum(self):
        row = jnp.array([1, 1, 2, 1], jnp.int32)
        col = jnp.array([2, 2, 0, 2], jnp.int32)
        val = jnp.array([1.0, 2.0, 5.0, 3.0])
        coo = COO.from_entries((4, 4), row, col, val, cap=8)
        d = coo.dedup(S.PLUS)
        dense = np.asarray(d.to_dense())
        assert dense[1, 2] == 6.0 and dense[2, 0] == 5.0
        assert int(d.nnz) == 2

    def test_dedup_generic_monoid(self):
        # non-tagged monoid: "concat-as-max-abs" — arbitrary associative op
        weird = S.Monoid(lambda a, b: jnp.where(jnp.abs(a) > jnp.abs(b), a, b),
                         0.0, None, "absmax")
        row = jnp.array([0, 0, 1], jnp.int32)
        col = jnp.array([0, 0, 1], jnp.int32)
        val = jnp.array([-5.0, 3.0, 2.0])
        coo = COO.from_entries((2, 2), row, col, val, cap=4)
        d = coo.dedup(weird)
        dense = np.asarray(d.to_dense())
        assert dense[0, 0] == -5.0 and dense[1, 1] == 2.0

    def test_transpose_prune_apply_reduce(self):
        rng = np.random.default_rng(2)
        dense, coo = rand_sparse(rng, 8, 8, 0.4)
        np.testing.assert_allclose(np.asarray(coo.transpose().to_dense()),
                                   dense.T)
        pruned = coo.prune(lambda v: v > 1.0)
        ref = np.where(dense > 1.0, dense, 0.0)
        np.testing.assert_allclose(np.asarray(pruned.to_dense()), ref)
        doubled = coo.apply(lambda v: v * 2)
        np.testing.assert_allclose(np.asarray(doubled.to_dense()), dense * 2)
        np.testing.assert_allclose(np.asarray(coo.reduce(1, S.PLUS)),
                                   dense.sum(1), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(coo.reduce(0, S.PLUS)),
                                   dense.sum(0), rtol=1e-6)

    def test_ewise(self):
        rng = np.random.default_rng(3)
        da, a = rand_sparse(rng, 10, 10, 0.3)
        db, b = rand_sparse(rng, 10, 10, 0.3)
        u = ewise_union(a, b, S.PLUS)
        np.testing.assert_allclose(np.asarray(u.to_dense()), da + db,
                                   rtol=1e-6)
        x = ewise_intersect(a, b, jnp.multiply)
        np.testing.assert_allclose(np.asarray(x.to_dense()), da * db,
                                   rtol=1e-6)

    def test_vector_valued_elements(self):
        # the paper's "neighborhood aggregation on vector data": val dims (3,)
        rng = np.random.default_rng(4)
        row = jnp.array([0, 1, 1], jnp.int32)
        col = jnp.array([1, 0, 0], jnp.int32)
        val = jnp.asarray(rng.random((3, 3)), jnp.float32)
        coo = COO.from_entries((2, 2), row, col, val, cap=6)
        d = coo.dedup(S.PLUS)
        out = np.asarray(d.to_dense())
        np.testing.assert_allclose(out[1, 0], np.asarray(val[1] + val[2]),
                                   rtol=1e-6)


SEMIRINGS = [S.ARITHMETIC, S.MIN_PLUS, S.MAX_MIN, S.BOOLEAN]


class TestLocalSpGEMM:
    @pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
    @pytest.mark.parametrize("algo", ["esc", "dense"])
    def test_vs_dense_oracle(self, sr, algo):
        rng = np.random.default_rng(7)
        m, k, n = 9, 11, 7
        da, a = rand_sparse(rng, m, k, 0.25)
        db, b = rand_sparse(rng, k, n, 0.25)
        if sr is S.BOOLEAN:
            a = a.apply(lambda v: v > 0)
            b = b.apply(lambda v: v > 0)
        zero = sr.add.identity
        ref = dense_semiring_mm(da, db, sr)
        if algo == "esc":
            c, ok = spgemm_esc(a, b, sr, prod_cap=512, out_cap=256)
        else:
            c, ok = spgemm_dense(a, b, sr, out_cap=256)
        assert bool(ok)
        got = np.asarray(c.to_dense(zero), np.float64)
        # implicit zeros: positions never touched hold `zero` in both
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)

    def test_flops_exact(self):
        rng = np.random.default_rng(8)
        da, a = rand_sparse(rng, 12, 12, 0.3)
        db, b = rand_sparse(rng, 12, 12, 0.3)
        expect = int(((da != 0).astype(np.int64).T @ (db != 0)).trace())
        # flops = sum_k nnz(A(:,k)) * nnz(B(k,:)) = trace(A_pat^T B_pat)?? no:
        expect = int(sum((da[:, k] != 0).sum() * (db[k, :] != 0).sum()
                         for k in range(12)))
        assert int(spgemm_flops(a, b)) == expect

    def test_auto_matches(self):
        rng = np.random.default_rng(9)
        da, a = rand_sparse(rng, 16, 16, 0.4)
        db, b = rand_sparse(rng, 16, 16, 0.4)
        c, ok = spgemm_auto(a, b, S.ARITHMETIC, prod_cap=2048, out_cap=512)
        assert bool(ok)
        np.testing.assert_allclose(np.asarray(c.to_dense()), da @ db,
                                   rtol=1e-5)

    def test_overflow_flag(self):
        rng = np.random.default_rng(10)
        da, a = rand_sparse(rng, 16, 16, 0.5)
        db, b = rand_sparse(rng, 16, 16, 0.5)
        _, ok = spgemm_esc(a, b, prod_cap=4, out_cap=4)
        assert not bool(ok)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.05, 0.5))
    def test_property_esc_equals_dense_path(self, seed, density):
        rng = np.random.default_rng(seed)
        da, a = rand_sparse(rng, 8, 8, density)
        db, b = rand_sparse(rng, 8, 8, density)
        c1, ok1 = spgemm_esc(a, b, prod_cap=1024, out_cap=256)
        c2, ok2 = spgemm_dense(a, b, out_cap=256)
        assert bool(ok1) and bool(ok2)
        np.testing.assert_allclose(np.asarray(c1.to_dense()),
                                   np.asarray(c2.to_dense()), rtol=1e-5)


class TestSpMV:
    @pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
    @pytest.mark.parametrize("variant", [spmv_row, spmv_col])
    def test_vs_dense(self, sr, variant):
        rng = np.random.default_rng(11)
        da, a = rand_sparse(rng, 14, 10, 0.3)
        x = jnp.asarray(rng.random(10).astype(np.float32) + 0.5)
        if sr is S.BOOLEAN:
            a = a.apply(lambda v: v > 0)
            x = x > 0
        y = variant(a, x, sr)
        # oracle: treat implicit zeros as absent
        ref = np.full(14, sr.add.identity, np.float64)
        for i in range(14):
            acc = sr.add.identity
            for j in range(10):
                if da[i, j] != 0:
                    p = np.asarray(sr.mul(jnp.float32(da[i, j]),
                                          x[j].astype(jnp.float32)))
                    acc = np.asarray(sr.add.op(jnp.float32(acc),
                                               jnp.float32(p)))
            ref[i] = acc
        np.testing.assert_allclose(np.asarray(y, np.float64), ref,
                                   rtol=1e-5, atol=1e-6)


class TestSpMSpV:
    @pytest.mark.parametrize("variant", [spmspv_sort, spmspv_spa,
                                         spmspv_bucket])
    @pytest.mark.parametrize("f", [1, 3, 8])
    def test_vs_spmv(self, variant, f):
        rng = np.random.default_rng(12)
        da, a = rand_sparse(rng, 20, 16, 0.25)
        xd = np.zeros(16, np.float32)
        nz = rng.choice(16, f, replace=False)
        xd[nz] = rng.random(f).astype(np.float32) + 0.5
        xi, xv, xnnz = spvec_from_dense(jnp.asarray(xd), cap=16)
        (yi, yv, ynnz), ok = variant(a, xi, xv, xnnz, S.ARITHMETIC,
                                     prod_cap=512, out_cap=64)
        assert bool(ok)
        got = np.asarray(spvec_to_dense(yi, yv, 20))
        np.testing.assert_allclose(got, da @ xd, rtol=1e-5, atol=1e-6)

    def test_min_plus_frontier(self):
        # BFS-ish: relax edges from a frontier under (min, +)
        rng = np.random.default_rng(13)
        da, a = rand_sparse(rng, 12, 12, 0.3)
        xd = np.full(12, np.inf, np.float32)
        xd[3] = 0.0
        xi = jnp.array([3] + [SENTINEL] * 3, jnp.int32)
        xv = jnp.array([0.0, np.inf, np.inf, np.inf], jnp.float32)
        (yi, yv, ynnz), ok = spmspv_sort(a, xi, xv, jnp.int32(1), S.MIN_PLUS,
                                         prod_cap=64, out_cap=32)
        got = np.asarray(spvec_to_dense(yi, yv, 12, zero=np.inf))
        ref = np.where(da[:, 3] != 0, da[:, 3] + 0.0, np.inf)
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    def test_auto_dispatch(self):
        rng = np.random.default_rng(14)
        da, a = rand_sparse(rng, 64, 64, 0.1)
        xd = np.zeros(64, np.float32)
        xd[rng.choice(64, 20, replace=False)] = 1.0
        xi, xv, xnnz = spvec_from_dense(jnp.asarray(xd), cap=64)
        (yi, yv, ynnz), ok = spmspv_auto(a, xi, xv, xnnz, S.ARITHMETIC,
                                         prod_cap=2048, out_cap=64)
        assert bool(ok)
        got = np.asarray(spvec_to_dense(yi, yv, 64))
        np.testing.assert_allclose(got, da @ xd, rtol=1e-5)


class TestSegmentReduce:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_generic_matches_fast(self, seed):
        rng = np.random.default_rng(seed)
        n, nseg = 50, 8
        ids = jnp.asarray(rng.integers(0, nseg, n), jnp.int32)
        vals = jnp.asarray(rng.random(n), jnp.float32)
        fast = S.segment_reduce(vals, ids, nseg, S.PLUS)
        generic = S.segment_reduce(vals, ids, nseg,
                                   S.Monoid(jnp.add, 0.0, None, "untagged"))
        np.testing.assert_allclose(np.asarray(fast), np.asarray(generic),
                                   rtol=1e-5)
