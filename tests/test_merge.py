"""Merge-engine equivalence tests (DESIGN.md §4.4).

Every engine primitive — packed-key dedup, sort-free ``dedup_sorted``,
rank-placement ``merge_sorted``/``merge_tree``, and the kv-level stage
pipeline — must agree with the seed implementation (``dedup_legacy``, the
two-key value-carrying sort) across tagged and untagged monoids, padded
and overflowing inputs. Property tests draw via hypothesis when installed
and degrade to the deterministic seeds otherwise (tests/_hypothesis_stub).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import given, settings, st

from repro.core import merge as M
from repro.core.coo import COO, SENTINEL, ewise_union
from repro.core.semiring import (ARITHMETIC, MAX, MAX_MIN, MIN, MIN_PLUS,
                                 Monoid, PLUS, Semiring, segment_reduce)

# user-defined untagged (but associative + commutative) monoid:
# a ⊕ b = a + b + a·b  (identity 0) — exercises the generic scan path
USER_ADD = Monoid(lambda a, b: a + b + a * b, 0.0, None, "user_probab")
USER_SR = Semiring(USER_ADD, jnp.multiply, "user")

MONOIDS = {
    "plus": (PLUS, 0.0),
    "min": (MIN, np.inf),
    "max": (MAX, -np.inf),
    "user": (USER_ADD, 0.0),
}


def rand_coo(n=24, cap=96, k=60, seed=0, fill=0.0, vdims=()):
    """Random tile with duplicate coordinates and cap padding."""
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, k)
    c = rng.integers(0, n, k)
    v = rng.random((k,) + vdims).astype(np.float32) + 0.25
    return COO.from_entries((n, n), r, c, v, cap=cap, fill=fill)


def dense_of(c: COO):
    return np.asarray(c.to_dense())


class TestPackedDedup:
    @pytest.mark.parametrize("name", sorted(MONOIDS))
    def test_matches_legacy(self, name):
        add, fill = MONOIDS[name]
        for seed in range(4):
            a = rand_coo(seed=seed, fill=fill)
            got = M.dedup(a, add)
            want = M.dedup_legacy(a, add)
            assert int(got.nnz) == int(want.nnz)
            np.testing.assert_allclose(dense_of(got), dense_of(want),
                                       rtol=1e-5, atol=1e-6)
            assert got.order == "row"

    def test_col_order(self):
        a = rand_coo(seed=3)
        got = M.dedup(a, PLUS, order="col")
        want = M.dedup_legacy(a, PLUS, order="col")
        np.testing.assert_allclose(dense_of(got), dense_of(want), rtol=1e-5)
        key = np.asarray(got.col).astype(np.int64) * 25 + np.asarray(got.row)
        k = int(got.nnz)
        assert np.all(np.diff(key[:k]) > 0)      # strictly col-major unique

    def test_vector_values(self):
        a = rand_coo(seed=5, vdims=(3,))
        got = M.dedup(a, PLUS)
        want = M.dedup_legacy(a, PLUS)
        np.testing.assert_allclose(dense_of(got), dense_of(want), rtol=1e-5)

    def test_dedup_sorted_skips_sort_same_result(self):
        a = rand_coo(seed=7)
        s = M.dedup(a, PLUS)                     # row-sorted unique, tagged
        again = s.dedup_sorted(PLUS)
        assert int(again.nnz) == int(s.nnz)
        np.testing.assert_allclose(dense_of(again), dense_of(s), rtol=1e-6)

    def test_unpackable_tile_falls_back(self):
        # (m+1)(n+1) >= 2^31 and no x64: key_dtype is None -> legacy path
        big = (1 << 16, 1 << 16)
        if jax.config.jax_enable_x64:
            pytest.skip("x64 enabled: packs into int64 instead")
        assert M.key_dtype(big) is None
        rng = np.random.default_rng(0)
        a = COO.from_entries(big, rng.integers(0, 1 << 16, 32),
                             rng.integers(0, 1 << 16, 32),
                             rng.random(32).astype(np.float32), cap=64)
        got = M.dedup(a, PLUS)                   # must not raise
        want = M.dedup_legacy(a, PLUS)
        assert int(got.nnz) == int(want.nnz)
        np.testing.assert_array_equal(np.asarray(got.row),
                                      np.asarray(want.row))


class TestMergeSorted:
    @pytest.mark.parametrize("name", sorted(MONOIDS))
    def test_matches_concat_dedup(self, name):
        add, fill = MONOIDS[name]
        for seed in range(3):
            a = M.dedup(rand_coo(seed=seed, fill=fill), add)
            b = M.dedup(rand_coo(seed=seed + 50, fill=fill), add)
            got = M.merge_sorted(a, b, add)
            both = COO(jnp.concatenate([a.row, b.row]),
                       jnp.concatenate([a.col, b.col]),
                       jnp.concatenate([a.val, b.val]),
                       a.nnz + b.nnz, a.shape, "none")
            want = M.dedup_legacy(both, add)
            assert int(got.nnz) == int(want.nnz)
            np.testing.assert_allclose(dense_of(got), dense_of(want),
                                       rtol=1e-5, atol=1e-6)

    def test_inputs_with_internal_duplicates(self):
        # merge_sorted must fuse within-stream duplicates too (general path)
        a = rand_coo(seed=11).sort("row")
        b = rand_coo(seed=12).sort("row")
        got = M.merge_sorted(a, b, PLUS)
        both = COO(jnp.concatenate([a.row, b.row]),
                   jnp.concatenate([a.col, b.col]),
                   jnp.concatenate([a.val, b.val]),
                   a.nnz + b.nnz, a.shape, "none")
        want = M.dedup_legacy(both, PLUS)
        np.testing.assert_allclose(dense_of(got), dense_of(want), rtol=1e-5)

    def test_merge_capped_overflow_flag(self):
        a = M.dedup(rand_coo(seed=1), PLUS)
        b = M.dedup(rand_coo(seed=2), PLUS)
        full = M.merge_sorted(a, b, PLUS)
        c, ok = M.merge_capped(a, b, PLUS, cap=int(full.nnz))
        assert bool(ok)
        c2, ok2 = M.merge_capped(a, b, PLUS, cap=int(full.nnz) - 1)
        assert not bool(ok2)                     # pre-clamp check trips

    def test_ewise_union_routes_through_engine(self):
        a = M.dedup(rand_coo(seed=21), PLUS)
        b = M.dedup(rand_coo(seed=22), PLUS)
        u = ewise_union(a, b, PLUS)
        np.testing.assert_allclose(dense_of(u),
                                   dense_of(a) + dense_of(b), rtol=1e-5)
        assert u.order == "row"


class TestMergeTree:
    @pytest.mark.parametrize("name", sorted(MONOIDS))
    def test_matches_legacy_fold(self, name):
        add, fill = MONOIDS[name]
        tiles = [M.dedup(rand_coo(seed=s, fill=fill), add) for s in range(5)]
        got, ok = M.merge_tree(tiles, add, out_cap=1024)
        assert bool(ok)
        # identity-filled dense images: the union-merge is the elementwise
        # monoid fold (op(identity, x) == x covers one-sided entries)
        want = np.asarray(tiles[0].to_dense(add.identity))
        for t in tiles[1:]:
            want = np.asarray(add.op(jnp.asarray(want),
                                     t.to_dense(add.identity)))
        np.testing.assert_allclose(np.asarray(got.to_dense(add.identity)),
                                   want, rtol=1e-5, atol=1e-6)

    def test_overflow_flag(self):
        tiles = [M.dedup(rand_coo(seed=s), PLUS) for s in range(4)]
        full, ok = M.merge_tree(tiles, PLUS, out_cap=4096)
        assert bool(ok)
        _, ok2 = M.merge_tree(tiles, PLUS, out_cap=int(full.nnz) - 1)
        assert not bool(ok2)


class TestKvStagePipeline:
    def _stages(self, q=4, n=32, per=40, prod_cap=256, seed=0):
        rng = np.random.default_rng(seed)
        stages = []
        for s in range(q):
            k = int(rng.integers(1, per))
            r = np.full(prod_cap, SENTINEL, np.int32)
            c = np.full(prod_cap, SENTINEL, np.int32)
            v = np.zeros(prod_cap, np.float32)
            r[:k] = rng.integers(0, n, k)
            c[:k] = rng.integers(0, n, k)
            v[:k] = rng.random(k).astype(np.float32) + 0.5
            stages.append((jnp.asarray(r), jnp.asarray(c), jnp.asarray(v),
                           jnp.asarray(k, jnp.int32)))
        return stages, (n, n)

    @pytest.mark.parametrize("stage_cap,prod_cap", [(256, 256), (64, 256)])
    def test_merge_stage_products_matches_legacy(self, stage_cap, prod_cap):
        # stage_cap < prod_cap exercises the windowed cond-skip compaction
        stages, shape = self._stages(prod_cap=prod_cap)
        got, ok = M.merge_stage_products(stages, shape, PLUS, stage_cap,
                                         out_cap=512)
        assert bool(ok)
        rows = jnp.concatenate([s[0] for s in stages])
        cols = jnp.concatenate([s[1] for s in stages])
        vals = jnp.concatenate([s[2] for s in stages])
        total = sum(s[3] for s in stages)
        want = M.dedup_legacy(
            COO(rows, cols, vals, total, shape, "none"), PLUS)
        assert int(got.nnz) == int(want.nnz)
        np.testing.assert_allclose(dense_of(got), dense_of(want), rtol=1e-5)
        assert got.order == "row"

    def test_stage_overflow_flag(self):
        stages, shape = self._stages()
        full, _ = M.merge_stage_products(stages, shape, PLUS, 256, 512)
        _, ok = M.merge_stage_products(stages, shape, PLUS, 256,
                                       out_cap=int(full.nnz) - 1)
        assert not bool(ok)

    def test_kv_merge2_unique_streams(self):
        a = M.dedup(rand_coo(seed=31), PLUS)
        b = M.dedup(rand_coo(seed=32), PLUS)
        ka = M.pack_keys(a.row, a.col, a.shape, "row")
        kb = M.pack_keys(b.row, b.col, b.shape, "row")
        k, v, n, ok = M.kv_merge2(ka, a.val, a.nnz, kb, b.val, b.nnz,
                                  PLUS, a.cap + b.cap)
        got = M.kv_to_coo(k, v, n, a.shape, PLUS, a.cap + b.cap)
        want = M.merge_sorted(a, b, PLUS)
        assert int(n) == int(want.nnz)
        np.testing.assert_allclose(dense_of(got), dense_of(want), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       name=st.sampled_from(sorted(MONOIDS)),
       cap=st.integers(40, 160))
def test_property_dedup_equivalence(seed, name, cap):
    add, fill = MONOIDS[name]
    a = rand_coo(cap=cap, k=min(cap, 40 + seed % 60), seed=seed, fill=fill)
    got = M.dedup(a, add)
    want = M.dedup_legacy(a, add)
    assert int(got.nnz) == int(want.nnz)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       name=st.sampled_from(sorted(MONOIDS)))
def test_property_merge_equivalence(seed, name):
    add, fill = MONOIDS[name]
    a = M.dedup(rand_coo(seed=seed, fill=fill), add)
    b = M.dedup(rand_coo(seed=seed + 1, fill=fill), add)
    got = M.merge_sorted(a, b, add)
    both = COO(jnp.concatenate([a.row, b.row]),
               jnp.concatenate([a.col, b.col]),
               jnp.concatenate([a.val, b.val]),
               a.nnz + b.nnz, a.shape, "none")
    want = M.dedup_legacy(both, add)
    assert int(got.nnz) == int(want.nnz)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-6)


def test_semiring_spgemm_equivalence():
    """spgemm_esc through the engine across semirings incl. user-defined."""
    from repro.core.local_spgemm import spgemm_esc
    rng = np.random.default_rng(0)
    n = 24
    d = np.where(rng.random((n, n)) < 0.25,
                 rng.random((n, n)).astype(np.float32) + 0.5, 0.0)
    A = COO.from_dense(jnp.asarray(d), cap=int((d != 0).sum()) + 8)
    for sr, ref in [
        (ARITHMETIC, lambda a, b: a @ b),
        (MIN_PLUS, lambda a, b: np.min(
            np.where((a[:, :, None] != 0) & (b[None, :, :] != 0),
                     a[:, :, None] + b[None, :, :], np.inf), axis=1)),
        (MAX_MIN, lambda a, b: np.max(
            np.where((a[:, :, None] != 0) & (b[None, :, :] != 0),
                     np.minimum(a[:, :, None], b[None, :, :]), -np.inf),
            axis=1)),
    ]:
        fill = sr.add.identity
        Af = COO(A.row, A.col, A.val, A.nnz, A.shape, A.order) \
            .canonicalize(fill)
        c, ok = spgemm_esc(Af, Af, sr, prod_cap=4096, out_cap=2048)
        assert bool(ok), sr.name
        want = ref(d, d)
        got = np.asarray(c.to_dense(fill))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
